"""K-step VMEM-resident PDES kernel (Pallas, TPU target).

Beyond-paper optimization B2 (DESIGN.md §5): the one-step kernel is
HBM-bandwidth-bound at ~12 bytes of traffic per PE-step (tau in/out + bits).
Keeping the ring resident in VMEM across K steps removes the tau round trips:

    traffic/step ≈ 8 bytes(bits) + 8/K bytes(tau)   → ~1.5× less at K = 16,
    and on real TPU with in-kernel RNG (pltpu.prng_*) the bits stream also
    disappears, leaving ~8/K bytes/PE-step — a K× intensity gain.

Because each program instance owns *entire rings* ``(block_b, L)``, the exact
global virtual time is available locally every step (a lane-wise min), so this
kernel implements the *paper-faithful* exact-GVT algorithm, not the stale-GVT
approximation.

Grid/tiling: grid = (ensemble blocks, K).  The K dimension is sequential
("arbitrary"): the tau tile is revisited — written at step k, re-read at
k + 1 — which Pallas guarantees for the same output block across grid steps.
Event bits are streamed one step at a time as ``(1, block_b, L, 2)`` tiles so
VMEM holds only one step's bits regardless of K.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tau_in_ref, bits_ref, tau_ref, ucount_ref, min_ref, sum_ref,
            sumsq_ref, *, n_v: int, delta: float, rd_mode: bool):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        tau_ref[...] = tau_in_ref[...]

    dtype = tau_ref.dtype
    tau = tau_ref[...]                      # (b, L) full rings
    bits = bits_ref[0]                      # (b, L, 2) this step's events

    site = jnp.remainder(bits[..., 0], jnp.uint32(n_v)).astype(jnp.int32)
    is_left = site == 0
    is_right = site == (n_v - 1)
    u = (bits[..., 1] >> jnp.uint32(8)).astype(dtype) * 2.0**-24
    eta = -jnp.log(u + 2.0**-25)

    left = jnp.roll(tau, 1, axis=-1)        # periodic: full ring resident
    right = jnp.roll(tau, -1, axis=-1)
    if rd_mode:
        causal_ok = jnp.ones(tau.shape, dtype=bool)
    else:
        ok_l = jnp.where(is_left, tau <= left, True)
        ok_r = jnp.where(is_right, tau <= right, True)
        causal_ok = ok_l & ok_r
    if math.isinf(delta):
        window_ok = jnp.ones(tau.shape, dtype=bool)
    else:
        gvt = jnp.min(tau, axis=-1, keepdims=True)   # exact GVT, in-VMEM
        window_ok = tau <= delta + gvt
    update = causal_ok & window_ok
    tau_next = tau + jnp.where(update, eta, 0.0)

    tau_ref[...] = tau_next
    ucount_ref[...] = jnp.sum(update.astype(dtype), axis=-1)[None, :]
    min_ref[...] = jnp.min(tau_next, axis=-1)[None, :]
    sum_ref[...] = jnp.sum(tau_next, axis=-1)[None, :]
    sumsq_ref[...] = jnp.sum(tau_next * tau_next, axis=-1)[None, :]


@functools.partial(
    jax.jit,
    static_argnames=("n_v", "delta", "rd_mode", "block_b", "interpret"),
)
def pdes_multistep(
    tau: jax.Array,
    bits: jax.Array,
    *,
    n_v: int,
    delta: float,
    rd_mode: bool = False,
    block_b: int = 8,
    interpret: bool = True,
):
    """K fused exact-GVT PDES steps on full rings.

    Args:
      tau: (B, L) full rings (periodic).
      bits: (K, B, L, 2) uint32 event bits for the K steps.

    Returns:
      (tau_final (B, L), stats dict of (K, B): ucount, min, sum, sumsq),
      per-step stats measured after each step's update.
    """
    B, L = tau.shape
    K = bits.shape[0]
    assert bits.shape == (K, B, L, 2)
    bb = min(block_b, B)
    while B % bb:
        bb -= 1
    grid = (B // bb, K)
    kern = functools.partial(_kernel, n_v=n_v, delta=delta, rd_mode=rd_mode)
    out_shape = [
        jax.ShapeDtypeStruct((B, L), tau.dtype),
        jax.ShapeDtypeStruct((K, B), tau.dtype),
        jax.ShapeDtypeStruct((K, B), tau.dtype),
        jax.ShapeDtypeStruct((K, B), tau.dtype),
        jax.ShapeDtypeStruct((K, B), tau.dtype),
    ]
    tau_final, ucount, mn, sm, ssq = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, L), lambda i, k: (i, 0)),
            pl.BlockSpec((1, bb, L, 2), lambda i, k: (k, i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, L), lambda i, k: (i, 0)),
            pl.BlockSpec((1, bb), lambda i, k: (k, i)),
            pl.BlockSpec((1, bb), lambda i, k: (k, i)),
            pl.BlockSpec((1, bb), lambda i, k: (k, i)),
            pl.BlockSpec((1, bb), lambda i, k: (k, i)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(tau, bits)
    stats = dict(ucount=ucount, min=mn, sum=sm, sumsq=ssq)
    return tau_final, stats

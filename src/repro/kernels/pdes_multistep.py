"""K-step VMEM-resident PDES kernels (Pallas, TPU target).

Beyond-paper optimization B2 (DESIGN.md §5): the one-step kernel is
HBM-bandwidth-bound at ~12 bytes of traffic per PE-step (tau in/out + bits).
Keeping the ring resident in VMEM across K steps removes the tau round trips:

    traffic/step ≈ 8 bytes(bits) + 8/K bytes(tau)   → ~1.5× less at K = 16.

Two variants share one step body (``_fused_step``, built on the shared core
in ``horizon``):

* ``pdes_multistep`` — event bits streamed from HBM one step at a time
  (arbitrary external streams, e.g. the jax.random stream of ``horizon``).
* ``pdes_multistep_counter`` — event bits generated **inside the kernel**
  from the counter-based stream (``events.counter_words`` on index iotas).
  No bits array exists at all: traffic drops to ~8/K bytes/PE-step, a K×
  intensity gain, and on CPU/interpret the murmur32 hash is far cheaper
  than host-side threefry.  This is the engine's fast path.

Because each program instance owns *entire rings* ``(block_b, L)``, the exact
global virtual time is available locally every step (a lane-wise min), so
these kernels implement the *paper-faithful* exact-GVT algorithm, not the
stale-GVT approximation.

Grid/tiling: grid = (ensemble blocks, K).  The K dimension is sequential
("arbitrary"): the tau tile is revisited — written at step k, re-read at
k + 1 — which Pallas guarantees for the same output block across grid steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core.events import counter_words
from ..core.horizon import (MOMENT_KEYS as STAT_KEYS, conservative_update,
                            decode_words, ring_moments)
from .tiling import pick_divisor_block


def _fused_step(tau, w0, w1, *, n_v, delta, rd_mode, border_both):
    """One in-VMEM update on full rings; returns (tau_next, moments)."""
    is_left, is_right, eta = decode_words(w0, w1, n_v, tau.dtype)
    left = jnp.roll(tau, 1, axis=-1)        # periodic: full ring resident
    right = jnp.roll(tau, -1, axis=-1)
    gvt = jnp.min(tau, axis=-1, keepdims=True)   # exact GVT, in-VMEM
    tau_next, update = conservative_update(
        tau, left, right, is_left, is_right, eta, gvt,
        delta=delta, rd_mode=rd_mode, border_both=border_both)
    return tau_next, ring_moments(tau_next, update)


def _write_step(tau_ref, stat_refs, tau_next, moments):
    tau_ref[...] = tau_next
    for key, ref in zip(STAT_KEYS, stat_refs):
        ref[...] = moments[key][None, :]


def _kernel_bits(tau_in_ref, bits_ref, tau_ref, *stat_refs,
                 n_v: int, delta: float, rd_mode: bool, border_both: bool):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        tau_ref[...] = tau_in_ref[...]

    tau = tau_ref[...]                      # (b, L) full rings
    bits = bits_ref[0]                      # (b, L, 2) this step's events
    tau_next, moments = _fused_step(
        tau, bits[..., 0], bits[..., 1],
        n_v=n_v, delta=delta, rd_mode=rd_mode, border_both=border_both)
    _write_step(tau_ref, stat_refs, tau_next, moments)


def _kernel_counter(ctr_ref, tau_in_ref, *refs,
                    n_v: int, delta: float, rd_mode: bool, border_both: bool,
                    block_b: int, has_delta_col: bool, has_trial_col: bool):
    refs = list(refs)
    if has_delta_col:
        delta = refs.pop(0)[...]            # (b, 1) per-row window widths
    trial_ref = refs.pop(0) if has_trial_col else None
    tau_ref, *stat_refs = refs
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        tau_ref[...] = tau_in_ref[...]

    tau = tau_ref[...]                      # (b, L) full rings
    b, L = tau.shape
    seed, step0, b0, l0 = (ctr_ref[0, i] for i in range(4))
    step = step0 + k.astype(jnp.uint32)
    if has_trial_col:
        bi = trial_ref[...]                 # (b, 1) per-row trial indices
    else:
        row0 = (pl.program_id(0) * block_b).astype(jnp.uint32)
        bi = b0 + row0 + jax.lax.broadcasted_iota(jnp.uint32, (b, L), 0)
    li = l0 + jax.lax.broadcasted_iota(jnp.uint32, (b, L), 1)
    w0, w1 = counter_words(seed, step, bi, li)
    tau_next, moments = _fused_step(
        tau, w0, w1,
        n_v=n_v, delta=delta, rd_mode=rd_mode, border_both=border_both)
    _write_step(tau_ref, stat_refs, tau_next, moments)


def _call_multistep(kern, inputs, in_specs, B, L, K, bb, dtype, interpret):
    out_shape = [jax.ShapeDtypeStruct((B, L), dtype)] + [
        jax.ShapeDtypeStruct((K, B), dtype) for _ in STAT_KEYS]
    row = pl.BlockSpec((1, bb), lambda i, k: (k, i))
    outs = pl.pallas_call(
        kern,
        grid=(B // bb, K),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((bb, L), lambda i, k: (i, 0))]
        + [row] * len(STAT_KEYS),
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)
    return outs[0], dict(zip(STAT_KEYS, outs[1:]))


@functools.partial(
    jax.jit,
    static_argnames=("n_v", "delta", "rd_mode", "border_both", "block_b",
                     "interpret"),
)
def pdes_multistep(
    tau: jax.Array,
    bits: jax.Array,
    *,
    n_v: int,
    delta: float,
    rd_mode: bool = False,
    border_both: bool = False,
    block_b: int = 8,
    interpret: bool = True,
):
    """K fused exact-GVT PDES steps on full rings, bits streamed from HBM.

    Args:
      tau: (B, L) full rings (periodic).
      bits: (K, B, L, 2) uint32 event bits for the K steps.

    Returns:
      (tau_final (B, L), stats dict of (K, B): ucount/min/max/sum/sumsq/
      sumabs), per-step stats measured after each step's update.
    """
    B, L = tau.shape
    K = bits.shape[0]
    assert bits.shape == (K, B, L, 2)
    bb = pick_divisor_block(B, block_b)
    kern = functools.partial(_kernel_bits, n_v=n_v, delta=delta,
                             rd_mode=rd_mode, border_both=border_both)
    in_specs = [
        pl.BlockSpec((bb, L), lambda i, k: (i, 0)),
        pl.BlockSpec((1, bb, L, 2), lambda i, k: (k, i, 0, 0)),
    ]
    return _call_multistep(kern, (tau, bits), in_specs, B, L, K, bb,
                           tau.dtype, interpret)


@functools.partial(
    jax.jit,
    static_argnames=("k_steps", "n_v", "delta", "rd_mode", "border_both",
                     "block_b", "interpret"),
)
def pdes_multistep_counter(
    tau: jax.Array,
    ctr: jax.Array,
    delta_col: jax.Array | None = None,
    trial_col: jax.Array | None = None,
    *,
    k_steps: int,
    n_v: int,
    delta: float,
    rd_mode: bool = False,
    border_both: bool = False,
    block_b: int = 8,
    interpret: bool = True,
):
    """K fused exact-GVT steps with the event stream generated in-kernel.

    Args:
      tau: (B, L) full rings (periodic).
      ctr: (1, 4) uint32 ``[seed, step0, b0, l0]`` — counter-stream seed,
        first step index, and global (trial, PE) offsets of this block.
        Steps k = 0..k_steps-1 consume stream step ``step0 + k``; the
        trajectory is bit-identical to feeding ``events.counter_bits`` into
        ``pdes_multistep``.
      delta_col: optional (B, 1) per-row window widths.  When given, the
        window bound becomes a *batched operand*: each ensemble row applies
        its own Δ (``inf`` rows = unconstrained) and the static ``delta``
        is ignored.  This is how one kernel pass serves a whole window
        sweep — the Δ grid rides on the ensemble axis.
      trial_col: optional (B, 1) uint32 per-row *global trial indices*.
        When given, row r's event stream is keyed on ``trial_col[r]``
        instead of ``b0 + r`` — the coalesced-batch operand of
        ``repro.service``, letting one pass pack rows from many requests on
        arbitrary (possibly duplicate) stream coordinates.  ``trial_col =
        b0 + arange(B)`` with ``ctr`` b0 zeroed is bit-identical to the
        scalar form.
      k_steps: number of fused steps (static).

    Returns: same as ``pdes_multistep``.
    """
    B, L = tau.shape
    assert ctr.shape == (1, 4) and ctr.dtype == jnp.uint32, (ctr.shape,
                                                             ctr.dtype)
    bb = pick_divisor_block(B, block_b)
    kern = functools.partial(_kernel_counter, n_v=n_v, delta=delta,
                             rd_mode=rd_mode, border_both=border_both,
                             block_b=bb, has_delta_col=delta_col is not None,
                             has_trial_col=trial_col is not None)
    in_specs = [
        pl.BlockSpec((1, 4), lambda i, k: (0, 0)),
        pl.BlockSpec((bb, L), lambda i, k: (i, 0)),
    ]
    inputs = [ctr, tau]
    if delta_col is not None:
        assert delta_col.shape == (B, 1), delta_col.shape
        in_specs.append(pl.BlockSpec((bb, 1), lambda i, k: (i, 0)))
        inputs.append(delta_col.astype(tau.dtype))
    if trial_col is not None:
        assert trial_col.shape == (B, 1), trial_col.shape
        in_specs.append(pl.BlockSpec((bb, 1), lambda i, k: (i, 0)))
        inputs.append(trial_col.astype(jnp.uint32))
    return _call_multistep(kern, tuple(inputs), in_specs, B, L, k_steps, bb,
                           tau.dtype, interpret)

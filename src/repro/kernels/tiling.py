"""Tile-size selection shared by the kernel wrappers and the engine.

One footprint model and one divisor rule, so the engine, the ops-level
budget check, and both kernel entry points can never disagree on tiling.
"""
from __future__ import annotations


def pick_divisor_block(B: int, block_b: int) -> int:
    """Largest divisor of ``B`` that is <= ``block_b`` (at least 1)."""
    bb = max(1, min(block_b, B))
    while B % bb:
        bb -= 1
    return bb


def vmem_bytes(L: int, block_b: int, *, in_kernel_bits: bool = False) -> int:
    """VMEM footprint estimate of one kernel tile.

    tau in/out tiles + the event words + per-row stats.  With in-kernel
    event generation (``pdes_multistep_counter``) the streamed bits tile is
    replaced by two transient uint32 word planes — the same 8 bytes/PE of
    VMEM, but zero HBM traffic; kept separate in case the models diverge.
    """
    tau_tile = block_b * (L + 2) * 4
    words = block_b * L * 8          # (w0, w1) planes or streamed bits tile
    stats = 6 * block_b * 4
    return 2 * tau_tile + words + stats


def pick_vmem_block(B: int, L: int, *, budget: int = 8 << 20,
                    in_kernel_bits: bool = False) -> int:
    """Largest divisor of ``B`` whose tile fits the VMEM budget."""
    bb = B
    while bb > 1 and vmem_bytes(L, bb, in_kernel_bits=in_kernel_bits) > budget:
        bb = (bb + 1) // 2
    return pick_divisor_block(B, bb)

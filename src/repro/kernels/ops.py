"""Public jit'd wrappers around the Pallas PDES kernels.

These present the same semantics as ``repro.core.horizon`` (identical event
stream, identical update rule) so the kernel path is a drop-in replacement
for the pure-XLA path — cross-validated in tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core import horizon
from ..core.horizon import PDESConfig
from . import tiling
from .pdes_step import pdes_step
from .pdes_multistep import pdes_multistep, pdes_multistep_counter  # noqa: F401  (re-export)


def ring_halo(tau: jax.Array) -> jax.Array:
    """(B, L) -> (B, L + 2) with periodic wrap columns."""
    return jnp.concatenate([tau[:, -1:], tau, tau[:, :1]], axis=1)


@functools.partial(jax.jit, static_argnames=("cfg", "interpret", "block_b"))
def step_ring(tau: jax.Array, bits: jax.Array, cfg: PDESConfig,
              *, interpret: bool = True, block_b: int = 8):
    """One fused step on full rings via the one-step kernel.

    Computes the exact GVT outside the kernel (one XLA reduction), then does
    the fused sweep.  Returns (tau_next, update-count stats dict).
    """
    gvt = jnp.min(tau, axis=-1, keepdims=True)
    return pdes_step(
        ring_halo(tau), bits, gvt,
        n_v=cfg.n_v, delta=cfg.delta, rd_mode=cfg.rd_mode,
        block_b=block_b, interpret=interpret,
    )


@functools.partial(jax.jit, static_argnames=("cfg", "n_steps", "interpret",
                                             "block_b", "k_fuse"))
def simulate(state: horizon.SimState, key: jax.Array, cfg: PDESConfig,
             n_steps: int, *, interpret: bool = True, block_b: int = 8,
             k_fuse: int = 16):
    """Kernel-path equivalent of ``horizon.run`` (exact algorithm).

    Runs ``n_steps`` in K-fused chunks via ``pdes_multistep``; emits per-step
    (utilization, w2, gvt) derived from the kernel's fused partial reductions
    through the shared ``horizon.stats_from_moments`` post-processing.

    Kept for the jax.random (threefry) event stream; the counter-stream
    engine (``repro.core.engine.PDESEngine``) supersedes this as the one
    entry point for multi-backend runs.

    Returns (final SimState, dict of (n_steps, B) arrays: u, w2, gvt).
    """
    B, L = state.tau.shape
    n_chunks, rem = divmod(n_steps, k_fuse)

    def chunk_body(carry, k):
        """k fused steps; k is static per call site."""
        tau, off, comp, step0 = carry
        # event bits for the k steps, keyed exactly like horizon._one_step
        steps = step0 + jnp.arange(k, dtype=jnp.int32)
        bits = jax.vmap(lambda s: horizon.event_bits(key, s, (B, L)))(steps)
        tau, moments = pdes_multistep(
            tau, bits, n_v=cfg.n_v, delta=cfg.delta, rd_mode=cfg.rd_mode,
            block_b=block_b, interpret=interpret)
        st = horizon.stats_from_moments(moments, off[None, :], L)
        # rebase once per chunk (fp32 hygiene; see horizon.SimState docstring)
        shift = jnp.min(tau, axis=-1)
        tau = tau - shift[:, None]
        off, comp = horizon._kahan_add(off, comp, shift)
        return (tau, off, comp, step0 + k), (st.utilization, st.w2, st.gvt)

    carry = (state.tau, state.offset, state.offset_comp, state.step)
    outs = []
    if n_chunks:
        carry, (u, w2, gvt) = jax.lax.scan(
            lambda c, _: chunk_body(c, k_fuse), carry, None, length=n_chunks)
        outs.append((u.reshape(-1, B), w2.reshape(-1, B), gvt.reshape(-1, B)))
    if rem:
        carry, (u, w2, gvt) = chunk_body(carry, rem)
        outs.append((u, w2, gvt))
    tau, off, comp, step = carry
    cat = lambda i: jnp.concatenate([o[i] for o in outs], axis=0)
    out = {"u": cat(0), "w2": cat(1), "gvt": cat(2)}
    return horizon.SimState(tau, off, comp, step), out


def vmem_bytes(cfg: PDESConfig, block_b: int, k_fuse: int = 1,
               in_kernel_bits: bool = False) -> int:
    """VMEM footprint estimate for tile-size selection (ops-level check).

    Delegates to the shared model in ``kernels.tiling`` (one footprint
    model for ops, kernels, and the engine); must stay well under ~16 MiB.
    """
    return tiling.vmem_bytes(cfg.L, block_b, in_kernel_bits=in_kernel_bits)


def pick_block_b(cfg: PDESConfig, budget: int = 8 << 20) -> int:
    """Largest power-of-two row block fitting the VMEM budget."""
    bb = 16
    while bb > 1 and vmem_bytes(cfg, bb) > budget:
        bb //= 2
    return bb

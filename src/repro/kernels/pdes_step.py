"""Fused one-step PDES update kernel (Pallas, TPU target).

The paper's hot spot is the per-step horizon sweep: in unfused form XLA emits
~7 HBM round trips per step (two rolls, two compares, a select, a min
reduction, stats).  This kernel performs them in a single VMEM pass:
read tau + event bits once, write tau' + per-row partial stats once.

Layout: the caller passes a *haloed* chunk ``tau`` of shape ``(B, Lc + 2)``
whose first/last columns hold the left/right neighbor values (wrap-around
columns for a full ring, or the halo received from neighbor shards in the
distributed runtime).  The window base ``gvt`` is supplied by the caller
(exact current minimum, or a stale/conservative bound — DESIGN.md B3), which
is how the engine exposes both window modes through one kernel.

The update rule itself is the shared core (``horizon.decode_words`` +
``horizon.conservative_update``) — the same traced code as the reference
scan and the sharded runtime, so cross-backend bit-parity is structural.
Per-row stats are the shared ``horizon.ring_moments`` reductions; ``sumabs``
is about the tile-local mean and is meaningful when the tile spans a full
ring (always the case for the engine and ``ops.step_ring``).

Grid/tiling: grid is over ensemble-row blocks; each program instance owns a
``(block_b, Lc + 2)`` VMEM tile.  Row blocks are independent, so the grid is
embarrassingly parallel ("parallel" dimension semantics).  The lane dimension
(Lc) is kept whole per tile because the neighbor stencil couples the entire
ring; VMEM budget is checked by the wrapper (ops.py).

TPU note: on CPU we validate with ``interpret=True``; on real TPU hardware
the uint32->exponential decode happens in VREGs and the kernel is purely
HBM-bandwidth-bound (arithmetic intensity ~1 flop/byte — see the roofline
discussion in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl

from ..core.horizon import (MOMENT_KEYS as STAT_KEYS, conservative_update,
                            decode_words, ring_moments)
from .tiling import pick_divisor_block


def _kernel(tau_ref, bits_ref, gvt_ref, out_ref, *stat_refs,
            n_v: int, delta: float, rd_mode: bool, border_both: bool):
    tau_h = tau_ref[...]                      # (b, Lc + 2) haloed
    tau = tau_h[:, 1:-1]
    bits = bits_ref[...]                      # (b, Lc, 2) uint32

    is_left, is_right, eta = decode_words(
        bits[..., 0], bits[..., 1], n_v, out_ref.dtype)
    tau_next, update = conservative_update(
        tau, tau_h[:, :-2], tau_h[:, 2:], is_left, is_right, eta,
        gvt_ref[...],                         # (b, 1) broadcast window base
        delta=delta, rd_mode=rd_mode, border_both=border_both)

    out_ref[...] = tau_next
    moments = ring_moments(tau_next, update)
    for key, ref in zip(STAT_KEYS, stat_refs):
        ref[...] = moments[key][:, None]


@functools.partial(
    jax.jit,
    static_argnames=("n_v", "delta", "rd_mode", "border_both", "block_b",
                     "interpret"),
)
def pdes_step(
    tau_haloed: jax.Array,
    bits: jax.Array,
    gvt: jax.Array,
    *,
    n_v: int,
    delta: float,
    rd_mode: bool = False,
    border_both: bool = False,
    block_b: int = 8,
    interpret: bool = True,
):
    """One fused PDES step on a haloed chunk.

    Args:
      tau_haloed: (B, Lc + 2) local times with neighbor halo columns.
      bits: (B, Lc, 2) uint32 event bits.
      gvt: (B, 1) window base.
      block_b: ensemble rows per VMEM tile.
      interpret: run the kernel body in interpret mode (CPU validation).

    Returns:
      (tau_next (B, Lc), stats dict of (B,): ucount/min/max/sum/sumsq/sumabs).
    """
    B, Lc2 = tau_haloed.shape
    Lc = Lc2 - 2
    assert bits.shape == (B, Lc, 2), (bits.shape, (B, Lc, 2))
    assert gvt.shape == (B, 1)
    bb = pick_divisor_block(B, block_b)
    grid = (B // bb,)
    kern = functools.partial(_kernel, n_v=n_v, delta=delta, rd_mode=rd_mode,
                             border_both=border_both)
    out_shape = [jax.ShapeDtypeStruct((B, Lc), tau_haloed.dtype)] + [
        jax.ShapeDtypeStruct((B, 1), tau_haloed.dtype) for _ in STAT_KEYS]
    col = pl.BlockSpec((bb, 1), lambda i: (i, 0))
    outs = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, Lc2), lambda i: (i, 0)),
            pl.BlockSpec((bb, Lc, 2), lambda i: (i, 0, 0)),
            col,
        ],
        out_specs=[pl.BlockSpec((bb, Lc), lambda i: (i, 0))]
        + [col] * len(STAT_KEYS),
        out_shape=out_shape,
        interpret=interpret,
    )(tau_haloed, bits, gvt)
    tau_next = outs[0]
    stats = {k: v[:, 0] for k, v in zip(STAT_KEYS, outs[1:])}
    return tau_next, stats

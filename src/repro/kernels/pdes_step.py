"""Fused one-step PDES update kernel (Pallas, TPU target).

The paper's hot spot is the per-step horizon sweep: in unfused form XLA emits
~7 HBM round trips per step (two rolls, two compares, a select, a min
reduction, stats).  This kernel performs them in a single VMEM pass:
read tau + event bits once, write tau' + per-row partial stats once.

Layout: the caller passes a *haloed* chunk ``tau`` of shape ``(B, Lc + 2)``
whose first/last columns hold the left/right neighbor values (wrap-around
columns for a full ring, or the halo received from neighbor shards in the
distributed runtime).  The window base ``gvt`` is supplied by the caller
(exact current minimum, or a stale/conservative bound — DESIGN.md B3).

Grid/tiling: grid is over ensemble-row blocks; each program instance owns a
``(block_b, Lc + 2)`` VMEM tile.  Row blocks are independent, so the grid is
embarrassingly parallel ("parallel" dimension semantics).  The lane dimension
(Lc) is kept whole per tile because the neighbor stencil couples the entire
ring; VMEM budget is checked by the wrapper (ops.py).

TPU note: on CPU we validate with ``interpret=True``; on real TPU hardware
the uint32->exponential decode happens in VREGs and the kernel is purely
HBM-bandwidth-bound (arithmetic intensity ~1 flop/byte — see the roofline
discussion in EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tau_ref, bits_ref, gvt_ref, out_ref, ucount_ref, min_ref,
            sum_ref, sumsq_ref, *, n_v: int, delta: float, rd_mode: bool):
    dtype = out_ref.dtype
    tau_h = tau_ref[...]                      # (b, Lc + 2) haloed
    tau = tau_h[:, 1:-1]
    left = tau_h[:, :-2]
    right = tau_h[:, 2:]
    bits = bits_ref[...]                      # (b, Lc, 2) uint32

    site = jnp.remainder(bits[..., 0], jnp.uint32(n_v)).astype(jnp.int32)
    is_left = site == 0
    is_right = site == (n_v - 1)
    u = (bits[..., 1] >> jnp.uint32(8)).astype(dtype) * 2.0**-24
    eta = -jnp.log(u + 2.0**-25)

    if rd_mode:
        causal_ok = jnp.ones(tau.shape, dtype=bool)
    else:
        ok_l = jnp.where(is_left, tau <= left, True)
        ok_r = jnp.where(is_right, tau <= right, True)
        causal_ok = ok_l & ok_r
    if math.isinf(delta):
        window_ok = jnp.ones(tau.shape, dtype=bool)
    else:
        window_ok = tau <= delta + gvt_ref[...]  # (b, 1) broadcast
    update = causal_ok & window_ok
    tau_next = tau + jnp.where(update, eta, 0.0)

    out_ref[...] = tau_next
    ucount_ref[...] = jnp.sum(update.astype(dtype), axis=-1, keepdims=True)
    min_ref[...] = jnp.min(tau_next, axis=-1, keepdims=True)
    sum_ref[...] = jnp.sum(tau_next, axis=-1, keepdims=True)
    sumsq_ref[...] = jnp.sum(tau_next * tau_next, axis=-1, keepdims=True)


@functools.partial(
    jax.jit,
    static_argnames=("n_v", "delta", "rd_mode", "block_b", "interpret"),
)
def pdes_step(
    tau_haloed: jax.Array,
    bits: jax.Array,
    gvt: jax.Array,
    *,
    n_v: int,
    delta: float,
    rd_mode: bool = False,
    block_b: int = 8,
    interpret: bool = True,
):
    """One fused PDES step on a haloed chunk.

    Args:
      tau_haloed: (B, Lc + 2) local times with neighbor halo columns.
      bits: (B, Lc, 2) uint32 event bits.
      gvt: (B, 1) window base.
      block_b: ensemble rows per VMEM tile.
      interpret: run the kernel body in interpret mode (CPU validation).

    Returns:
      (tau_next (B, Lc), stats dict of (B,): ucount, min, sum, sumsq).
    """
    B, Lc2 = tau_haloed.shape
    Lc = Lc2 - 2
    assert bits.shape == (B, Lc, 2), (bits.shape, (B, Lc, 2))
    assert gvt.shape == (B, 1)
    bb = min(block_b, B)
    while B % bb:
        bb -= 1
    grid = (B // bb,)
    kern = functools.partial(_kernel, n_v=n_v, delta=delta, rd_mode=rd_mode)
    out_shape = [
        jax.ShapeDtypeStruct((B, Lc), tau_haloed.dtype),
        jax.ShapeDtypeStruct((B, 1), tau_haloed.dtype),
        jax.ShapeDtypeStruct((B, 1), tau_haloed.dtype),
        jax.ShapeDtypeStruct((B, 1), tau_haloed.dtype),
        jax.ShapeDtypeStruct((B, 1), tau_haloed.dtype),
    ]
    tau_next, ucount, mn, sm, ssq = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, Lc2), lambda i: (i, 0)),
            pl.BlockSpec((bb, Lc, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bb, Lc), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
            pl.BlockSpec((bb, 1), lambda i: (i, 0)),
        ],
        out_shape=out_shape,
        interpret=interpret,
    )(tau_haloed, bits, gvt)
    stats = dict(ucount=ucount[:, 0], min=mn[:, 0], sum=sm[:, 0], sumsq=ssq[:, 0])
    return tau_next, stats

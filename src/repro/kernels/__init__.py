"""Pallas TPU kernels for the PDES hot loop (validated in interpret mode on CPU)."""
from .ops import pdes_step, pdes_multistep, step_ring, simulate, ring_halo  # noqa: F401

"""Pallas TPU kernels for the PDES hot loop (validated in interpret mode on CPU)."""
from .ops import (  # noqa: F401
    pdes_multistep,
    pdes_multistep_counter,
    pdes_step,
    pick_block_b,
    ring_halo,
    simulate,
    step_ring,
)

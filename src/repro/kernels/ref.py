"""Pure-jnp oracles for the Pallas PDES kernels.

Each function mirrors the corresponding kernel's arithmetic *exactly* — by
construction, since both sides call the shared update core in
``repro.core.horizon`` (``decode_words`` / ``conservative_update`` /
``ring_moments``) — so the kernel tests assert bitwise or near-bitwise
equality and exercise only the Pallas machinery (tiling, grid revisiting,
in-kernel event generation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.events import counter_words
from ..core.horizon import conservative_update, decode_words, ring_moments


def decode(bits: jnp.ndarray, n_v: int, dtype=jnp.float32):
    """bits (..., 2) uint32 -> (is_left, is_right, eta).  Mirrors the kernels."""
    return decode_words(bits[..., 0], bits[..., 1], n_v, dtype)


def pdes_step_ref(
    tau_haloed: jnp.ndarray,
    bits: jnp.ndarray,
    gvt: jnp.ndarray,
    *,
    n_v: int,
    delta: float,
    rd_mode: bool = False,
    border_both: bool = False,
):
    """Oracle for kernels.pdes_step: one step on a haloed chunk.

    Args:
      tau_haloed: (B, Lc + 2) with halo columns at [:, 0] and [:, -1].
      bits: (B, Lc, 2) uint32 event bits for the interior.
      gvt: (B, 1) window base (exact or stale global virtual time).
      n_v, delta, rd_mode: PDES parameters (delta may be inf).

    Returns:
      (tau_next (B, Lc), update (B, Lc) bool,
       stats dict of (B,) arrays: ucount/min/max/sum/sumsq/sumabs).
    """
    tau = tau_haloed[:, 1:-1]
    is_left, is_right, eta = decode(bits, n_v, tau_haloed.dtype)
    tau_next, update = conservative_update(
        tau, tau_haloed[:, :-2], tau_haloed[:, 2:], is_left, is_right, eta,
        gvt, delta=delta, rd_mode=rd_mode, border_both=border_both)
    return tau_next, update, ring_moments(tau_next, update)


def _multistep_body(n_v, delta, rd_mode, border_both, dtype):
    def body(tau, words):
        w0, w1 = words
        is_left, is_right, eta = decode_words(w0, w1, n_v, dtype)
        left = jnp.roll(tau, 1, axis=-1)
        right = jnp.roll(tau, -1, axis=-1)
        gvt = jnp.min(tau, axis=-1, keepdims=True)  # exact: full ring in block
        tau_next, update = conservative_update(
            tau, left, right, is_left, is_right, eta, gvt,
            delta=delta, rd_mode=rd_mode, border_both=border_both)
        return tau_next, ring_moments(tau_next, update)

    return body


def pdes_multistep_ref(
    tau: jnp.ndarray,
    bits: jnp.ndarray,
    *,
    n_v: int,
    delta: float,
    rd_mode: bool = False,
    border_both: bool = False,
):
    """Oracle for kernels.pdes_multistep: K exact-GVT steps on full rings.

    Args:
      tau: (B, L) full rings (no halo; periodic).
      bits: (K, B, L, 2) uint32 event bits.

    Returns:
      (tau_final (B, L), stats dict of (K, B): ucount/min/max/sum/sumsq/
      sumabs) where per-step stats are measured *after* that step's update.
    """
    body = _multistep_body(n_v, delta, rd_mode, border_both, tau.dtype)
    return jax.lax.scan(body, tau, (bits[..., 0], bits[..., 1]))


def pdes_multistep_counter_ref(
    tau: jnp.ndarray,
    ctr: jnp.ndarray,
    *,
    k_steps: int,
    n_v: int,
    delta: float,
    rd_mode: bool = False,
    border_both: bool = False,
):
    """Oracle for kernels.pdes_multistep_counter (in-kernel event stream)."""
    B, L = tau.shape
    seed, step0, b0, l0 = (ctr[0, i] for i in range(4))
    bi = b0 + jnp.arange(B, dtype=jnp.uint32)[:, None]
    li = l0 + jnp.arange(L, dtype=jnp.uint32)[None, :]
    body = _multistep_body(n_v, delta, rd_mode, border_both, tau.dtype)

    def step(tau, k):
        w0, w1 = counter_words(seed, step0 + k, bi, li)
        return body(tau, jnp.broadcast_arrays(w0, w1))

    return jax.lax.scan(step, tau, jnp.arange(k_steps, dtype=jnp.uint32))

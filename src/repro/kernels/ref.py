"""Pure-jnp oracles for the Pallas PDES kernels.

Each function mirrors the corresponding kernel's arithmetic *exactly*
(same event decode, same op order) so the kernel tests can assert bitwise
or near-bitwise equality.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def decode(bits: jnp.ndarray, n_v: int, dtype=jnp.float32):
    """bits (..., 2) uint32 -> (is_left, is_right, eta).  Mirrors the kernels."""
    site = jnp.remainder(bits[..., 0], jnp.uint32(n_v)).astype(jnp.int32)
    is_left = site == 0
    is_right = site == (n_v - 1)
    u = (bits[..., 1] >> jnp.uint32(8)).astype(dtype) * 2.0**-24
    eta = -jnp.log(u + 2.0**-25)
    return is_left, is_right, eta


def pdes_step_ref(
    tau_haloed: jnp.ndarray,
    bits: jnp.ndarray,
    gvt: jnp.ndarray,
    *,
    n_v: int,
    delta: float,
    rd_mode: bool = False,
):
    """Oracle for kernels.pdes_step: one step on a haloed chunk.

    Args:
      tau_haloed: (B, Lc + 2) with halo columns at [:, 0] and [:, -1].
      bits: (B, Lc, 2) uint32 event bits for the interior.
      gvt: (B, 1) window base (exact or stale global virtual time).
      n_v, delta, rd_mode: PDES parameters (delta may be inf).

    Returns:
      (tau_next (B, Lc), update (B, Lc) bool,
       stats dict of (B,) arrays: ucount, min, sum, sumsq).
    """
    dtype = tau_haloed.dtype
    tau = tau_haloed[:, 1:-1]
    left = tau_haloed[:, :-2]
    right = tau_haloed[:, 2:]
    is_left, is_right, eta = decode(bits, n_v, dtype)
    if rd_mode:
        causal_ok = jnp.ones(tau.shape, dtype=bool)
    else:
        ok_l = jnp.where(is_left, tau <= left, True)
        ok_r = jnp.where(is_right, tau <= right, True)
        causal_ok = ok_l & ok_r
    if math.isinf(delta):
        window_ok = jnp.ones(tau.shape, dtype=bool)
    else:
        window_ok = tau <= delta + gvt
    update = causal_ok & window_ok
    tau_next = tau + jnp.where(update, eta, 0.0)
    stats = dict(
        ucount=jnp.sum(update.astype(dtype), axis=-1),
        min=jnp.min(tau_next, axis=-1),
        sum=jnp.sum(tau_next, axis=-1),
        sumsq=jnp.sum(tau_next * tau_next, axis=-1),
    )
    return tau_next, update, stats


def pdes_multistep_ref(
    tau: jnp.ndarray,
    bits: jnp.ndarray,
    *,
    n_v: int,
    delta: float,
    rd_mode: bool = False,
):
    """Oracle for kernels.pdes_multistep: K exact-GVT steps on full rings.

    Args:
      tau: (B, L) full rings (no halo; periodic).
      bits: (K, B, L, 2) uint32 event bits.

    Returns:
      (tau_final (B, L), stats dict of (K, B): ucount, min, sum, sumsq)
      where per-step stats are measured *after* that step's update.
    """
    dtype = tau.dtype
    K = bits.shape[0]

    def body(tau, bits_k):
        is_left, is_right, eta = decode(bits_k, n_v, dtype)
        left = jnp.roll(tau, 1, axis=-1)
        right = jnp.roll(tau, -1, axis=-1)
        if rd_mode:
            causal_ok = jnp.ones(tau.shape, dtype=bool)
        else:
            ok_l = jnp.where(is_left, tau <= left, True)
            ok_r = jnp.where(is_right, tau <= right, True)
            causal_ok = ok_l & ok_r
        if math.isinf(delta):
            window_ok = jnp.ones(tau.shape, dtype=bool)
        else:
            gvt = jnp.min(tau, axis=-1, keepdims=True)  # exact: full ring in block
            window_ok = tau <= delta + gvt
        update = causal_ok & window_ok
        tau_next = tau + jnp.where(update, eta, 0.0)
        stats = (
            jnp.sum(update.astype(dtype), axis=-1),
            jnp.min(tau_next, axis=-1),
            jnp.sum(tau_next, axis=-1),
            jnp.sum(tau_next * tau_next, axis=-1),
        )
        return tau_next, stats

    tau_final, (ucount, mins, sums, sumsqs) = jax.lax.scan(body, tau, bits)
    return tau_final, dict(ucount=ucount, min=mins, sum=sums, sumsq=sumsqs)

"""Roofline-term extraction from compiled dry-run artifacts (DESIGN.md §7).

    compute  = HLO_FLOPs_per_device / PEAK_FLOPS
    memory   = HLO_bytes_per_device / HBM_BW
    collect. = collective_bytes_per_device / ICI_BW

cost_analysis() provides per-device FLOPs/bytes of the SPMD module.
Collective bytes are NOT in cost_analysis: we parse the optimized HLO and sum
operand/result sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute op (shapes in the SPMD module are already
per-device shard shapes).
"""
from __future__ import annotations

import dataclasses
import re

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
# ops that appear in HLO with these prefixes, including -start variants
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind result-shape bytes of collective ops in an (SPMD) HLO module.

    Result shapes approximate the per-device payload: exact for all-reduce
    and collective-permute, ~the moved volume for all-gather (result spans
    the gathered tensor); reduce-scatter counts operand shapes instead.
    ``-done`` halves of async pairs are skipped to avoid double counting.
    """
    out = {k: 0 for k in _COLL_KINDS}
    counts = {k: 0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        post = line.split(" = ", 1)[1]
        op_pos = post.find(kind)
        result_text = post[:op_pos]
        if kind == "reduce-scatter":
            b = _shape_bytes(post[op_pos:])       # operand shapes in the args
        else:
            b = _shape_bytes(result_text)
        out[kind] += b
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLL_KINDS)
    out["counts"] = counts
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    coll_detail: dict

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(compiled, *, n_devices: int, model_flops: float) -> Roofline:
    """Roofline terms from the compiled artifact.

    FLOPs/bytes/collectives come from the trip-count-aware HLO analysis
    (hlo_cost.analyze_hlo) because XLA's cost_analysis counts while bodies
    once (verified in tests/test_roofline.py); the raw cost_analysis values
    are kept in coll_detail as a cross-check.
    """
    from .hlo_cost import analyze_hlo
    txt = compiled.as_text()
    cost = analyze_hlo(txt)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = cost.flops
    byts = cost.bytes
    t_c = flops / PEAK_FLOPS_BF16
    t_m = byts / HBM_BW
    t_x = cost.coll_bytes / ICI_BW
    dom = max((("compute", t_c), ("memory", t_m), ("collective", t_x)),
              key=lambda kv: kv[1])[0]
    useful = model_flops / (flops * n_devices) if flops else 0.0
    return Roofline(
        flops_per_dev=flops, bytes_per_dev=byts,
        coll_bytes_per_dev=cost.coll_bytes,
        compute_s=t_c, memory_s=t_m, collective_s=t_x, dominant=dom,
        model_flops=model_flops, useful_ratio=useful,
        coll_detail=dict(cost.coll, msgs=cost.coll_msgs,
                         xla_flops_body_once=float(ca.get("flops", -1.0)),
                         xla_bytes_body_once=float(ca.get("bytes accessed", -1.0))),
    )


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D train / 2·N·D prefill / 2·N·B decode (per step)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n * shape.seq_len * shape.global_batch
    return 2.0 * n * shape.global_batch      # decode: one token per lane

"""Abstract input specs (ShapeDtypeStruct) for every (arch x shape) cell.

No device allocation: train states and KV caches are built with
jax.eval_shape against the model's own init/cache constructors, so the
dry-run lowers exactly what the real launcher would execute.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig

WHISPER_ENC_LEN = 1536    # stub frontend frames for decode cells (~30 s audio)


def _cd(cfg: ModelConfig):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.compute_dtype]


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig):
    """Model inputs for one step of the given kind."""
    B, S = shape.global_batch, shape.seq_len
    tok = sds((B, S), jnp.int32)
    if shape.kind in ("train",):
        if cfg.family == "encdec":
            return {"enc_embeddings": sds((B, S, cfg.d_model), _cd(cfg)),
                    "tokens": tok, "labels": tok}
        if cfg.input_mode == "embeddings":
            return {"embeddings": sds((B, S, cfg.d_model), _cd(cfg)),
                    "labels": tok}
        return {"tokens": tok, "labels": tok}
    if shape.kind == "prefill":
        if cfg.family == "encdec":
            return {"enc_embeddings": sds((B, S, cfg.d_model), _cd(cfg))}
        if cfg.input_mode == "embeddings":
            return {"embeddings": sds((B, S, cfg.d_model), _cd(cfg))}
        return {"tokens": tok}
    if shape.kind == "decode":
        return {"tokens": sds((B, 1), jnp.int32)}
    raise ValueError(shape.kind)


def abstract_params(model, cfg: ModelConfig):
    return jax.eval_shape(model.init, jax.random.key(0))


def abstract_cache(model, cfg: ModelConfig, shape: ShapeConfig):
    """KV/SSM cache avals for decode cells."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "ssm":
        return jax.eval_shape(lambda: model.init_cache(B))
    if cfg.family == "hybrid":
        return jax.eval_shape(lambda: model.init_cache(B, S))
    if cfg.family == "encdec":
        return jax.eval_shape(
            lambda: model.init_cache(B, S, WHISPER_ENC_LEN))
    return jax.eval_shape(lambda: model.cache_spec(B, S))


def abstract_state(model, cfg: ModelConfig):
    from ..train.train_step import init_train_state
    return jax.eval_shape(lambda k: init_train_state(model, k),
                          jax.random.key(0))

"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 300 --batch 8 --seq 256 --reduced

Runs on whatever devices exist (CPU smoke / real TPU pod unchanged): builds
the mesh, the Δ-window scheduler, the deterministic pipeline, the jitted
train step with shardings, and the fault-tolerant controller.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from ..configs import get_config
from ..data.pipeline import DataConfig, make_batch
from ..distributed.delta_sync import DeltaScheduler, DeltaSyncConfig
from ..optim.adamw import AdamWConfig
from ..train.fault import FaultInjector, RecoveryConfig, TrainController
from ..train.train_step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true",
                    help="use the tiny smoke config (CPU-friendly)")
    ap.add_argument("--delta", type=float, default=4.0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, ce_chunk=min(cfg.ce_chunk, args.seq))

    opt = AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps)
    model, step_fn = make_train_step(cfg, None, opt)
    state = init_train_state(model, jax.random.key(0))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree.leaves(state["params"]))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"devices={jax.device_count()}")

    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                    global_batch=args.batch)
    scheduler = DeltaScheduler(
        DeltaSyncConfig(n_workers=max(jax.device_count(), 2),
                        delta=args.delta))
    ctl = TrainController(
        jax.jit(step_fn), state, lambda s: make_batch(dc, s),
        RecoveryConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        scheduler=scheduler,
        injector=FaultInjector(tuple(args.fail_at)) if args.fail_at else None)

    t0 = time.time()
    log = ctl.run(args.steps)
    dt = time.time() - t0
    losses = [m["loss"] for m in log]
    print(f"steps={len(log)} restarts={ctl.restarts} "
          f"time={dt:.1f}s ({dt/max(len(log),1)*1e3:.0f} ms/step)")
    print(f"loss: first={losses[0]:.3f} last={losses[-1]:.3f} "
          f"min={min(losses):.3f}")
    print(f"Δ-window: utilization={scheduler.utilization:.3f} "
          f"gvt={scheduler.gvt:.1f} spread={scheduler.spread:.2f} (Δ={args.delta})")
    return log


if __name__ == "__main__":
    main()

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh, the abstract train state /
serve cache with full shardings, and runs ``jit(step).lower(...).compile()``.
Success proves the distribution config is coherent; memory_analysis() proves
it fits; cost_analysis() + HLO collective parsing feed §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
      --shape train_4k --mesh single --out results/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --cells all --mesh both

The PDES core itself is also dry-runnable as the pseudo-arch ``pdes-core``
(ring of 2^20 PEs x 512 trials), proving the paper's own workload shards.
"""
import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCH_IDS, cell_is_runnable, get_config, get_shape
from ..configs.base import SHAPES
from ..distributed.sharding import (Parallelism, batch_pspecs, cache_pspecs,
                                    param_pspecs, to_shardings)
from ..launch import roofline as RL
from ..launch.mesh import make_production_mesh
from ..launch.specs import (abstract_cache, abstract_params, abstract_state,
                            batch_specs)
from ..optim.adamw import AdamWConfig
from ..train.train_step import (make_decode_step, make_prefill_step,
                                make_train_step, state_pspecs)


def _parallelism(mesh, joint_batch: bool = False,
                 serve: bool = False) -> Parallelism:
    multi = "pod" in mesh.axis_names
    return Parallelism(
        mesh=mesh,
        dp_axes=("pod", "data") if multi else ("data",),
        fsdp_axis=None if serve else "data",
        tp_axis="model",
        joint_batch=joint_batch,
        serve=serve,
    )


def lower_cell(arch: str, shape_name: str, mesh, *, compile_: bool = True,
               overrides: dict | None = None, joint_batch: bool | None = None):
    """Returns (record dict, compiled or lowered)."""
    shape = get_shape(shape_name)
    cfg = get_config(arch)
    if joint_batch is None:
        # A5 profile measured as a net loss under current GSPMD (see
        # EXPERIMENTS.md §Perf A5) — off by default, available via the flag.
        joint_batch = False
    par = _parallelism(mesh, joint_batch, serve=(shape.kind == "decode"))
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    t0 = time.time()

    if shape.kind == "train":
        model, step = make_train_step(cfg, par, AdamWConfig())
        state = abstract_state(model, cfg)
        batch = batch_specs(cfg, shape)
        st_specs = state_pspecs(state, par)
        b_specs = batch_pspecs(batch, par)
        in_sh = (to_shardings(st_specs, mesh), to_shardings(b_specs, mesh))
        fn = jax.jit(step, in_shardings=in_sh,
                     out_shardings=(in_sh[0], None), donate_argnums=0)
        lowered = fn.lower(state, batch)
    elif shape.kind == "prefill":
        model, step = make_prefill_step(cfg, par)
        params = abstract_params(model, cfg)
        batch = batch_specs(cfg, shape)
        p_specs = param_pspecs(params, par)
        b_specs = batch_pspecs(batch, par)
        in_sh = (to_shardings(p_specs, mesh), to_shardings(b_specs, mesh))
        fn = jax.jit(step, in_shardings=in_sh)
        lowered = fn.lower(params, batch)
    else:  # decode
        model, step = make_decode_step(cfg, par)
        params = abstract_params(model, cfg)
        cache = abstract_cache(model, cfg, shape)
        batch = batch_specs(cfg, shape)
        p_specs = param_pspecs(params, par)
        c_specs = cache_pspecs(cache, par)
        b_specs = batch_pspecs(batch, par)
        in_sh = (to_shardings(p_specs, mesh), to_shardings(c_specs, mesh),
                 to_shardings(b_specs["tokens"], mesh), None)
        fn = jax.jit(step, in_shardings=in_sh,
                     out_shardings=(None, to_shardings(c_specs, mesh)),
                     donate_argnums=1)
        lowered = fn.lower(params, cache, batch["tokens"],
                           jax.ShapeDtypeStruct((), jnp.int32))

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_devices": mesh.devices.size,
        "lower_s": round(time.time() - t0, 1),
    }
    if not compile_:
        return rec, lowered
    t1 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t1, 1)
    ma = compiled.memory_analysis()
    rec["memory"] = {
        "argument_gib": ma.argument_size_in_bytes / 2**30,
        "output_gib": ma.output_size_in_bytes / 2**30,
        "temp_gib": ma.temp_size_in_bytes / 2**30,
        "alias_gib": ma.alias_size_in_bytes / 2**30,
        "peak_gib": (ma.argument_size_in_bytes + ma.output_size_in_bytes
                     + ma.temp_size_in_bytes - ma.alias_size_in_bytes) / 2**30,
    }
    rl = RL.analyze(compiled, n_devices=mesh.devices.size,
                    model_flops=RL.model_flops_for(cfg, shape))
    rec["roofline"] = rl.to_dict()
    return rec, compiled


def run_cells(cells, meshes, out_dir: pathlib.Path, overrides=None):
    out_dir.mkdir(parents=True, exist_ok=True)
    ok = fail = 0
    for mesh_name in meshes:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        for arch, shape in cells:
            tag = f"{arch}__{shape}__{mesh_name}"
            path = out_dir / f"{tag}.json"
            if not cell_is_runnable(arch, shape):
                path.write_text(json.dumps(
                    {"arch": arch, "shape": shape, "mesh": mesh_name,
                     "status": "skipped",
                     "reason": "sub-quadratic rule (DESIGN.md §6)"}, indent=1))
                print(f"[skip] {tag}")
                continue
            try:
                rec, _ = lower_cell(arch, shape, mesh, overrides=overrides)
                rec["status"] = "ok"
                ok += 1
                print(f"[ok]   {tag}  lower={rec['lower_s']}s "
                      f"compile={rec.get('compile_s')}s "
                      f"dom={rec['roofline']['dominant']}")
            except Exception as e:  # noqa: BLE001 - record and continue
                rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                       "status": "fail", "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                fail += 1
                print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:200]}")
            path.write_text(json.dumps(rec, indent=1))
    print(f"done: {ok} ok, {fail} failed")
    return fail


def pdes_core_cell(mesh_name: str, out_dir: pathlib.Path):
    """Dry-run the paper's own workload on the production mesh."""
    from ..core.distributed import DistConfig, lower_sharded
    from ..core.horizon import PDESConfig
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    multi = mesh_name == "multi"
    cfg = PDESConfig(L=1 << 20, n_v=100, delta=100.0)
    for mode in ("exact", "commavoid"):
        dist = DistConfig(
            ens_axes=("pod", "data") if multi else ("data",),
            ring_axis="model", mode=mode, k_chunk=16)
        from ..launch.hlo_cost import analyze_hlo
        t0 = time.time()
        lowered = lower_sharded(cfg, mesh, n_trials=512, n_steps=64, dist=dist)
        compiled = lowered.compile()
        ma = compiled.memory_analysis()
        cost = analyze_hlo(compiled.as_text())     # trip-count aware
        rec = {
            "arch": "pdes-core", "mode": mode, "mesh": mesh_name,
            "status": "ok", "compile_s": round(time.time() - t0, 1),
            "L": cfg.L, "trials": 512, "steps": 64,
            "flops_per_dev": cost.flops,
            "bytes_per_dev": cost.bytes,
            "coll_bytes_per_step": cost.coll_bytes / 64,
            "coll_msgs_per_step": cost.coll_msgs / 64,
            "collectives": dict(cost.coll),
            "memory_temp_gib": ma.temp_size_in_bytes / 2**30,
        }
        (out_dir / f"pdes-core__{mode}__{mesh_name}.json").write_text(
            json.dumps(rec, indent=1))
        print(f"[ok]   pdes-core {mode} {mesh_name} "
              f"coll/step={cost.coll_bytes / 64:.3g}B "
              f"msgs/step={cost.coll_msgs / 64:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--cells", default=None, help="'all' or 'arch:shape,...'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--pdes-core", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    if args.pdes_core:
        for m in meshes:
            pdes_core_cell(m, out)
        return
    if args.cells == "all":
        cells = [(a, s) for a in ARCH_IDS for s in SHAPES]
    elif args.cells:
        cells = [tuple(c.split(":")) for c in args.cells.split(",")]
    else:
        cells = [(args.arch, args.shape)]
    raise SystemExit(1 if run_cells(cells, meshes, out) else 0)


if __name__ == "__main__":
    main()

"""Trip-count-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, which makes
it useless for scan-over-layers modules (verified in tests/test_roofline.py).
This module re-derives flops / HBM bytes / collective bytes by parsing the
optimized HLO, building the computation call graph, and multiplying loop-body
costs by the ``known_trip_count`` backend_config XLA attaches after loop
analysis.

Accounting rules:
* flops: 2·prod(result)·prod(contracting dims) per ``dot`` (propagated
  through fusions, whiles and calls).  Elementwise flops are ignored — the
  models here are dot-dominated, and the compute roofline term cares about
  MXU work.
* bytes: Σ(result + operand bytes) of every *top-level* op in a computation
  (fusion internals never touch HBM, so fusion-called computations contribute
  flops but not bytes).
* collectives: result bytes (operand bytes for reduce-scatter) per op,
  multiplied by enclosing loop trip counts; message counts tracked too.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")
_CALLS = re.compile(r"(?:calls=|to_apply=|body=)%?([\w\.\-]+)")
_COND = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP = re.compile(r'known_trip_count"?\s*[:=]\s*\{\s*"?n"?\s*[:=]\s*"?(\d+)')
_OPCODE_AFTER = re.compile(r"\s*([a-z][a-z0-9\-]*)\s*\(")

COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
              "collective-permute")


def _result_prefix_len(rhs: str) -> int:
    """Length of the result-type prefix of an op's RHS.

    Tuple result types nest arbitrarily — ``(f32[2], (f32[4], s32[]))`` —
    so a balanced-paren scan is required; a ``\\([^)]*\\)`` regex stops at
    the first ``)`` and mis-locates the opcode (and with it the operand
    list).  Non-tuple results are ``dtype[dims]{layout?}``.
    """
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return i + 1
        return 0
    m = re.match(r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?", rhs)
    return m.end() if m else 0


def _shape_elems_bytes(text: str):
    total_b = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
    return total_b


def _dims_list(attr: str, name: str):
    m = re.search(name + r"=\{([0-9,]*)\}", attr)
    if not m or not m.group(1):
        return []
    return [int(x) for x in m.group(1).split(",")]


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_text: str
    full_text: str
    args_start: int = -1      # index of the operand list's "(" in full_text


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    shapes: dict = dataclasses.field(default_factory=dict)      # name -> dims
    shape_bytes: dict = dataclasses.field(default_factory=dict)  # name -> bytes
    convert_src: dict = dataclasses.field(default_factory=dict)  # name -> src bytes


def parse_computations(hlo: str) -> dict:
    comps = {}
    cur = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
            # header: [ENTRY] %name (params...) -> type {   (params may nest parens)
            tok = line.strip().split()[0]
            if tok == "ENTRY":
                tok = line.strip().split()[1]
            name = tok.lstrip("%").split("(")[0]
            if name:
                cur = Computation(name, [])
                comps[cur.name] = cur
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        prefix = _result_prefix_len(rhs)
        om = _OPCODE_AFTER.match(rhs[prefix:]) if prefix else None
        if om:
            opcode = om.group(1)
            args_start = prefix + om.end() - 1
            result_text = rhs[:prefix]
        else:
            opcode = rhs.split("(")[0].strip().split()[-1]
            args_start = rhs.find("(")
            result_text = rhs[:rhs.find(opcode)] if opcode in rhs else rhs
        cur.ops.append(Op(name, opcode, result_text, rhs, args_start))
        sm = _SHAPE_RE.search(result_text)
        if sm:
            cur.shapes[name] = [int(x) for x in sm.group(2).split(",") if x]
            cur.shape_bytes[name] = float(_shape_elems_bytes(result_text))
    return comps


def _dot_flops(op: Op, comp: "Computation") -> float:
    """2 * prod(result dims) * prod(lhs contracting dim sizes)."""
    result_b = _SHAPE_RE.findall(op.result_text)
    if not result_b:
        return 0.0
    res_elems = 1
    for d in result_b[0][1].split(","):
        if d:
            res_elems *= int(d)
    # lhs shape: inline in args, or looked up from the producing op
    arg_texts, arg_names = _split_args(op)
    first_arg = arg_texts[0] if arg_texts else ""
    lhs_m = _SHAPE_RE.search(first_arg)
    if lhs_m:
        lhs_dims = [int(x) for x in lhs_m.group(2).split(",") if x]
    else:
        lhs_dims = comp.shapes.get(arg_names[0] if arg_names else None, None)
        if lhs_dims is None:
            return 2.0 * res_elems  # unknown K: floor at K=1
    contract = _dims_list(op.full_text, "lhs_contracting_dims")
    k = 1
    for c in contract:
        if c < len(lhs_dims):
            k *= lhs_dims[c]
    return 2.0 * res_elems * k


_SLICING_OPS = ("dynamic-slice", "slice", "gather")
_NO_BYTES_OPS = ("parameter", "constant", "tuple", "get-tuple-element",
                 "bitcast", "while", "conditional", "call", "after-all",
                 "iota", "partition-id", "replica-id")


def _dims_bytes(dims, dt_bytes):
    n = 1
    for d in dims:
        n *= d
    return n * dt_bytes


def _split_args(op: Op):
    """Top-level operand names of an op (stripping inline shapes).

    Splits only on commas at paren depth 1 *outside* any bracket/brace
    nesting: shape dims (``f32[32,256]``), layouts (``{1,0}``) and tuple
    types (``(s32[], f32[2,2])``) all contain commas that must not split —
    miscounting here shifts operand↔parameter alignment and silently charges
    sliced fusion params their full operand bytes.

    The scan starts at ``op.args_start`` — the opcode's own paren, located
    while parsing — NOT at the first ``(`` of the line, which for tuple-
    typed ops (``%t = (f32[2], s32[]) tuple(...)``) belongs to the result
    type and would mis-split the operand list.
    """
    txt = op.full_text
    start = op.args_start if op.args_start >= 0 else txt.find("(")
    depth = 0          # paren depth ( )
    nest = 0           # bracket/brace depth [ ] { }
    args, cur = [], []
    for ch in txt[start:]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args.append("".join(cur).strip())
                break
        elif ch in "[{":
            nest += 1
        elif ch in "]}":
            nest -= 1
        elif ch == "," and depth == 1 and nest == 0:
            args.append("".join(cur).strip())
            cur = []
            continue
        cur.append(ch)
    names = []
    for a in args:
        m = re.search(r"%([\w\.\-]+)\s*$", a)
        names.append(m.group(1) if m else None)
    return args, names


def _operand_bytes(arg_text: str, name, comp: "Computation") -> float:
    m = _SHAPE_RE.search(arg_text)
    if m:
        return _shape_elems_bytes(arg_text)
    if name is not None and name in comp.shapes:
        # dims only; dtype unknown from name — assume 4 bytes... instead look
        # up the producing op's result text for dtype correctness
        return comp.shape_bytes.get(name, 0.0)
    return 0.0


def _fusion_bytes(op: Op, comp: "Computation", comps: dict) -> float:
    """Fusion interface traffic; slice-only-consumed params count slice bytes."""
    b = _shape_elems_bytes(op.result_text)
    fm = re.search(r"calls=%?([\w\.\-]+)", op.full_text)
    called = comps.get(fm.group(1)) if fm else None
    arg_texts, arg_names = _split_args(op)
    if called is None:
        for t, n in zip(arg_texts, arg_names):
            b += _operand_bytes(t, n, comp)
        return b
    # map parameter index -> uses inside the fused computation
    params = {}
    for o in called.ops:
        if o.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", o.full_text)
            if pm:
                params[int(pm.group(1))] = o.name
    for i, (t, n) in enumerate(zip(arg_texts, arg_names)):
        pname = params.get(i)
        full = _operand_bytes(t, n, comp)
        if pname is None:
            b += full
            continue
        pat = re.compile(r"%" + re.escape(pname) + r"\b")
        uses = [o for o in called.ops
                if o.name != pname and pat.search(o.full_text)]
        if uses and all(u.opcode in _SLICING_OPS for u in uses):
            b += sum(_shape_elems_bytes(u.result_text) for u in uses)
        else:
            b += full
    return b


def _convert_only(op: Op, comps: dict) -> bool:
    """True for CPU-inserted dtype-convert fusions (absent on TPU: the MXU
    consumes/produces bf16 natively, so these round trips are artifacts of
    compiling the dry-run for the host backend)."""
    if op.opcode != "fusion":
        return False
    fm = re.search(r"calls=%?([\w\.\-]+)", op.full_text)
    called = comps.get(fm.group(1)) if fm else None
    if called is None:
        return False
    body = [o for o in called.ops if o.opcode != "parameter"]
    return len(body) == 1 and body[0].opcode == "convert"


def _op_bytes(op: Op, comp: "Computation", comps: dict) -> float:
    """HBM traffic estimate for one top-level op."""
    if op.opcode in _NO_BYTES_OPS:
        return 0.0
    if op.opcode == "fusion":
        if _convert_only(op, comps):
            return 0.0
        return _fusion_bytes(op, comp, comps)
    if op.opcode == "dot":
        # count operands at their pre-convert dtype (TPU-native bf16 flow)
        res = _shape_elems_bytes(op.result_text)
        arg_texts, arg_names = _split_args(op)
        total = res
        for t, n in zip(arg_texts, arg_names):
            b = _operand_bytes(t, n, comp)
            src = comp.convert_src.get(n)
            total += src if src is not None else b
        return total
    if op.opcode == "convert":
        return 0.0
    res = _shape_elems_bytes(op.result_text)
    arg_texts, arg_names = _split_args(op)
    if op.opcode in ("dynamic-slice", "slice"):
        return 2.0 * res                      # read slice + write result
    if op.opcode == "gather":
        idx = _operand_bytes(arg_texts[1], arg_names[1], comp) \
            if len(arg_texts) > 1 else 0.0
        return 2.0 * res + idx
    if op.opcode == "dynamic-update-slice":
        upd = _operand_bytes(arg_texts[1], arg_names[1], comp) \
            if len(arg_texts) > 1 else 0.0
        return 2.0 * upd                      # in-place aliased update
    if op.opcode == "scatter":
        upd = _operand_bytes(arg_texts[-1], arg_names[-1], comp)
        idx = _operand_bytes(arg_texts[1], arg_names[1], comp) \
            if len(arg_texts) > 2 else 0.0
        return 2.0 * upd + idx
    return res + sum(_operand_bytes(t, n, comp)
                     for t, n in zip(arg_texts, arg_names))


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLL_KINDS})
    coll_msgs: float = 0.0

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m,
                    {k: v * m for k, v in self.coll.items()},
                    self.coll_msgs * m)

    def add(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k in COLL_KINDS:
            self.coll[k] += o.coll[k]
        self.coll_msgs += o.coll_msgs

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


_CP_PAIRS = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")
_CP_PAIR = re.compile(r"\{(\d+),(\d+)\}")


def collective_permutes(hlo: str) -> list:
    """``source_target_pairs`` of every collective-permute in the HLO text.

    Returns one ``[(source, target), ...]`` list per op carrying the
    attribute (``collective-permute`` and its ``-start`` async form) — the
    raw stencil of the program's point-to-point communication, consumed by
    the ``stencil-locality`` rule in ``repro.analysis``.
    """
    out = []
    for comp in parse_computations(hlo).values():
        for op in comp.ops:
            if not op.opcode.startswith("collective-permute"):
                continue
            m = _CP_PAIRS.search(op.full_text)
            if m:
                out.append([(int(a), int(b))
                            for a, b in _CP_PAIR.findall(m.group(1))])
    return out


def analyze_hlo(hlo: str, entry: str | None = None) -> Cost:
    comps = parse_computations(hlo)
    # post-pass: record convert-only fusions' source sizes for dot accounting
    for comp in comps.values():
        for op in comp.ops:
            if _convert_only(op, comps):
                arg_texts, arg_names = _split_args(op)
                if arg_texts:
                    comp.convert_src[op.name] = _operand_bytes(
                        arg_texts[0], arg_names[0], comp)
    memo: dict[str, Cost] = {}
    # entry computation: the one named in "ENTRY %name" line
    em = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo, re.M)
    entry = entry or (em.group(1) if em else next(iter(comps)))

    def comp_cost(name: str, count_bytes: bool) -> Cost:
        key = f"{name}|{count_bytes}"
        if key in memo:
            return memo[key]
        memo[key] = Cost()          # break cycles defensively
        c = Cost()
        comp = comps.get(name)
        if comp is None:
            return c
        for op in comp.ops:
            if op.opcode == "dot":
                c.flops += _dot_flops(op, comp)
            kind = next((k for k in COLL_KINDS
                         if op.opcode == k or op.opcode == k + "-start"), None)
            if kind:
                if kind == "reduce-scatter":
                    s = op.args_start if op.args_start >= 0 \
                        else op.full_text.find("(")
                    args = op.full_text[s:]
                    c.coll[kind] += _shape_elems_bytes(args)
                else:
                    c.coll[kind] += _shape_elems_bytes(op.result_text)
                c.coll_msgs += 1
            if count_bytes:
                c.bytes += _op_bytes(op, comp, comps)
            # propagate into called computations
            if op.opcode == "while":
                trips = 1.0
                tm = _TRIP.search(op.full_text)
                if tm:
                    trips = float(tm.group(1))
                bm = re.search(r"body=%?([\w\.\-]+)", op.full_text)
                if bm:
                    c.add(comp_cost(bm.group(1), count_bytes).scaled(trips))
                cm = _COND.search(op.full_text)
                if cm:
                    c.add(comp_cost(cm.group(1), False).scaled(trips))
            elif op.opcode in ("fusion",):
                fm = re.search(r"calls=%?([\w\.\-]+)", op.full_text)
                if fm:
                    # fusion internals: flops yes, HBM bytes no
                    c.add(comp_cost(fm.group(1), False))
            elif op.opcode in ("call", "async-start"):
                fm = re.search(r"(?:to_apply|calls)=%?([\w\.\-]+)", op.full_text)
                if fm:
                    c.add(comp_cost(fm.group(1), count_bytes))
            elif op.opcode == "conditional":
                for br in re.findall(r"branch_computations=\{([^}]*)\}",
                                     op.full_text):
                    for b in br.split(","):
                        c.add(comp_cost(b.strip().lstrip("%"), count_bytes))
        memo[key] = c
        return c

    return comp_cost(entry, True)

"""Production mesh factory.

Defined as a function (never a module-level constant) so importing this
module never touches jax device state.  The dry-run entrypoint sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real (1-device) platform.
"""
from __future__ import annotations

import jax

from ..compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = jax.device_count()
    if shape is None:
        shape = (1, n)
    return make_mesh(shape, axes)


# TPU v5e hardware constants used by the roofline analysis (DESIGN.md §7)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link

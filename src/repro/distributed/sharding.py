"""Sharding rules: logical param/activation roles -> PartitionSpecs.

Strategy (DESIGN.md §4): FSDP over the ``data`` axis (parameter dim-0
sharding, ZeRO-3 style all-gather on use) combined with tensor parallelism
over the ``model`` axis (Megatron column/row sharding of attention heads and
FFN hidden).  Batch shards over (pod, data).  Dims that do not divide their
axis fall back to replication (e.g. arctic's 56 heads on a 16-way TP axis).

MoE expert weights keep experts unsharded and shard d_ff over TP ("TP-MoE",
see models/moe.py docstring); an EP alternative is a §Perf experiment.

KV caches shard batch over dp and sequence over TP (sequence-parallel cache)
so decode_32k (B=128) and long_500k (B=1) both fit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import keystr


@dataclasses.dataclass(frozen=True)
class Parallelism:
    mesh: Mesh
    dp_axes: tuple[str, ...] = ("data",)     # batch (includes "pod" if present)
    fsdp_axis: Optional[str] = "data"        # param dim-0 sharding
    tp_axis: Optional[str] = "model"
    # §Perf A5 (expert-parallel joint-batch profile): batch shards over
    # (dp, tp) everywhere, MoE experts shard over tp with a dispatch
    # all-to-all, dense FFN/vocab give up tp sharding (they are small in the
    # MoE archs this targets).  Requires global_batch % (dp·tp) == 0.
    joint_batch: bool = False
    # Decode profile (§Perf D1): weights stay *resident* (no FSDP all-gather
    # per decode step) — experts shard over the data axis (EP) + d_ff/heads
    # over TP.  Only safe when the resident shard fits HBM (all 10 archs do).
    serve: bool = False

    def axis_size(self, name) -> int:
        if name is None:
            return 1
        if isinstance(name, tuple):
            s = 1
            for n in name:
                s *= self.mesh.shape[n]
            return s
        return self.mesh.shape[name]

    def div(self, axis, dim: int):
        """axis name if dim divides the axis size, else None (replicate)."""
        return axis if axis is not None and dim % self.axis_size(axis) == 0 \
            else None


def _param_spec(path: str, shape, par: Parallelism) -> P:
    """Spec for the *trailing* (base) dims; leading stack dims -> None."""
    fs, tp = par.fsdp_axis, par.tp_axis
    name = path.split("'")[-2] if "'" in path else path

    def base() -> tuple:
        if par.serve:
            return _serve_base(name, path, shape, par)
        if name in ("table", "lm_head"):                 # (V, d) vocab-TP
            if par.joint_batch:                          # A5: vocab replicated
                return (None, par.div(fs, shape[-1]))
            return (par.div(tp, shape[-2]), par.div(fs, shape[-1]))
        if name == "pos_table":
            return (None, par.div(fs, shape[-1]))
        if name == "wq":                                 # (d, H, hd)
            return (par.div(fs, shape[-3]), par.div(tp, shape[-2]), None)
        if name in ("wk", "wv"):                         # (d, KH, hd)
            return (par.div(fs, shape[-3]), par.div(tp, shape[-2]), None)
        if name == "wo" and len(shape) >= 3 and "'moe'" not in path:
            return (par.div(tp, shape[-3]), None, par.div(fs, shape[-1]))
        if "'moe'" in path:
            if par.joint_batch:                          # A5: EP over tp
                if name in ("wi", "wg"):                 # (E, d, f)
                    return (par.div(tp, shape[-3]), par.div(fs, shape[-2]),
                            None)
                if name == "wo":                         # (E, f, d)
                    return (par.div(tp, shape[-3]), None,
                            par.div(fs, shape[-1]))
            if name in ("wi", "wg"):                     # (E, d, f)
                return (None, par.div(fs, shape[-2]), par.div(tp, shape[-1]))
            if name == "wo":                             # (E, f, d)
                return (None, par.div(tp, shape[-2]), par.div(fs, shape[-1]))
            if name == "router":                         # (d, E)
                return (par.div(fs, shape[-2]), None)
        if name in ("wi", "wg"):                         # (d, f)
            if par.joint_batch:                          # A5: FSDP only
                return (par.div(fs, shape[-2]), None)
            return (par.div(fs, shape[-2]), par.div(tp, shape[-1]))
        if name == "wo":                                 # (f, d)
            if par.joint_batch:
                return (None, par.div(fs, shape[-1]))
            return (par.div(tp, shape[-2]), par.div(fs, shape[-1]))
        if name in ("in_proj", "shared_in"):             # (d, proj)
            return (par.div(fs, shape[-2]), par.div(tp, shape[-1]))
        if name == "out_proj":                           # (d_inner, d)
            return (par.div(tp, shape[-2]), par.div(fs, shape[-1]))
        if name == "conv_w":                             # (K, C)
            return (None, par.div(tp, shape[-1]))
        return tuple(None for _ in shape)                # vectors, norms, A_log…

    b = base()
    pad = len(shape) - len(b)
    assert pad >= 0, (path, shape, b)
    return P(*((None,) * pad + tuple(b)))


def _serve_base(name, path, shape, par: Parallelism):
    """Resident-weight decode sharding: no FSDP axis; EP + TP only."""
    tp = par.tp_axis
    ep = "data"          # experts over the data axis (batch is small at decode)
    if name in ("table", "lm_head", "pos_table"):
        return (par.div(tp, shape[-2]), None)
    if name == "wq" or (name in ("wk", "wv")):           # (d, H|KH, hd)
        if shape[-2] % par.axis_size(tp) == 0:
            return (None, tp, None)
        return (None, None, par.div(tp, shape[-1]))      # shard head_dim
    if name == "wo" and len(shape) >= 3 and "'moe'" not in path:
        if shape[-3] % par.axis_size(tp) == 0:           # (H, hd, d)
            return (tp, None, None)
        return (None, par.div(tp, shape[-2]), None)
    if "'moe'" in path:
        if name in ("wi", "wg"):                          # (E, d, f)
            return (par.div(ep, shape[-3]), None, par.div(tp, shape[-1]))
        if name == "wo":                                  # (E, f, d)
            return (par.div(ep, shape[-3]), par.div(tp, shape[-2]), None)
        if name == "router":
            return (None, None)
    if name in ("wi", "wg", "in_proj", "shared_in"):      # (d, f)
        return (None, par.div(tp, shape[-1]))
    if name in ("wo", "out_proj"):                        # (f, d)
        return (par.div(tp, shape[-2]), None)
    if name == "conv_w":
        return (None, par.div(tp, shape[-1]))
    return tuple(None for _ in shape)


def param_pspecs(params, par: Parallelism):
    """PartitionSpec pytree matching a (real or ShapeDtypeStruct) param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda kp, a: _param_spec(keystr(kp), a.shape, par), params)


def param_shardings(params, par: Parallelism):
    return jax.tree.map(lambda s: NamedSharding(par.mesh, s),
                        param_pspecs(params, par))


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------


def make_constrain(par: Parallelism, n_heads: int | None = None):
    """constrain(x, kind) applying with_sharding_constraint by logical role.

    ``n_heads``: the model's attention head count; the A2 joint-batch
    attention layout only activates when heads do NOT divide the TP axis
    (otherwise head-TP is already optimal and the extra resharding costs
    10-20x in backward all-gathers — measured, EXPERIMENTS.md §Perf A2).
    """
    dp, tp = par.dp_axes, par.tp_axis
    heads_divisible = (n_heads is None or tp is None
                       or n_heads % par.axis_size(tp) == 0)

    def joint_batch(b):
        axes = tuple(dp) + ((tp,) if tp is not None else ())
        return axes if b % par.axis_size(axes) == 0 else None

    def spec_for(x, kind) -> P | None:
        if kind == "act":            # (B, S, d)
            if par.joint_batch:
                j = joint_batch(x.shape[0])
                if j is not None:
                    return P(j, None, None)
            return P(par.div(dp, x.shape[0]), None, None)
        if kind == "attn_in":        # (B, S, d) entering attention (§Perf A2)
            if not heads_divisible:
                j = joint_batch(x.shape[0])
                if j is not None:
                    return P(j, None, None)
            return P(par.div(dp, x.shape[0]), None, None)
        if kind == "act_ff":         # (B, S, f)
            if par.joint_batch:      # A5: dense FFN keeps the joint batch
                j = joint_batch(x.shape[0])
                if j is not None:
                    return P(j, None, None)
            return P(par.div(dp, x.shape[0]), None, par.div(tp, x.shape[-1]))
        if kind in ("heads", "kv_heads"):   # (B, S, H, hd)
            if heads_divisible:
                # q heads TP-shard; GQA kv heads (< tp) replicate — small,
                # and flash broadcasts them across q groups.
                return P(par.div(dp, x.shape[0]), None,
                         par.div(tp, x.shape[2]), None)
            # §Perf A2: the MODEL's heads don't divide TP (arctic 56H,
            # whisper/gemma2 8H on a 16-way axis) — shard the batch over
            # (dp, tp) jointly so attention work still spreads over every
            # chip; the batch split happens on the (B,S,d) "attn_in" input
            # and is pulled back to dp-only at the attention output ("act").
            j = joint_batch(x.shape[0])
            if j is not None:
                return P(j, None, None, None)
            return P(par.div(dp, x.shape[0]), None, None, None)
        if kind == "logits":         # (B, chunk, V)
            if par.joint_batch:
                j = joint_batch(x.shape[0])
                if j is not None:
                    return P(j, None, None)
            return P(par.div(dp, x.shape[0]), None, par.div(tp, x.shape[-1]))
        if kind == "moe_hidden":     # (B, E, C, f)
            if par.joint_batch:      # A5: EP — experts over tp, batch over dp
                return P(par.div(dp, x.shape[0]), par.div(tp, x.shape[1]),
                         None, None)
            return P(par.div(dp, x.shape[0]), None, None,
                     par.div(tp, x.shape[-1]))
        if kind == "moe_in":         # (B, E, C, d) dispatch tensor (A5 only)
            if par.joint_batch:
                return P(par.div(dp, x.shape[0]), par.div(tp, x.shape[1]),
                         None, None)
            return None
        if kind == "moe_out":        # (B, E, C, d) combine tensor (A5 only)
            if par.joint_batch:
                j = joint_batch(x.shape[0])
                if j is not None:
                    return P(j, None, None, None)
            return None
        return None

    def constrain(x, kind):
        s = spec_for(x, kind)
        if s is None:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(par.mesh, s))

    return constrain


# ---------------------------------------------------------------------------
# batches and caches
# ---------------------------------------------------------------------------


def batch_pspecs(batch, par: Parallelism):
    def spec(a):
        axes = par.dp_axes
        if par.joint_batch and par.tp_axis is not None:
            joint = tuple(par.dp_axes) + (par.tp_axis,)
            if a.shape[0] % par.axis_size(joint) == 0:
                axes = joint
        return P(par.div(axes, a.shape[0]), *(None,) * (a.ndim - 1))
    return jax.tree.map(spec, batch)


def cache_pspecs(cache, par: Parallelism):
    """KV caches (..., B, S, KH, hd) -> batch over dp, sequence over TP.

    SSM states (..., B, H, P, N) -> batch over dp, heads over TP.
    Conv caches (..., B, K-1, C) -> batch over dp, channels over TP.
    """
    dp, tp = par.dp_axes, par.tp_axis

    def spec(path, a):
        name = keystr(path)
        if a.ndim >= 4 and ("'k" in name or "'v" in name or "xk" in name
                            or "xv" in name):
            lead = a.ndim - 4
            return P(*(None,) * lead, par.div(dp, a.shape[lead]),
                     par.div(tp, a.shape[lead + 1]), None, None)
        if "ssm" in name and a.ndim >= 4:
            lead = a.ndim - 4
            return P(*(None,) * lead, par.div(dp, a.shape[lead]),
                     par.div(tp, a.shape[lead + 1]), None, None)
        if "conv" in name and a.ndim >= 3:
            lead = a.ndim - 3
            return P(*(None,) * lead, par.div(dp, a.shape[lead]), None,
                     par.div(tp, a.shape[lead + 2]))
        return P(*(None,) * a.ndim)

    return jax.tree_util.tree_map_with_path(spec, cache)


def to_shardings(pspecs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs,
        is_leaf=lambda x: isinstance(x, P))

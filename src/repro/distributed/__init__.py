"""Distribution layer: sharding rules, Δ-window bounded-asynchrony scheduler."""
from .sharding import (Parallelism, batch_pspecs, cache_pspecs,  # noqa: F401
                       make_constrain, param_pspecs, param_shardings,
                       to_shardings)
from .delta_sync import (DeltaScheduler, DeltaSyncConfig,  # noqa: F401
                         gated_microbatch_weights, predicted_utilization)

"""Δ-window bounded-asynchrony for data-parallel training (the paper's
technique as a first-class training-runtime feature; DESIGN.md §3).

Mapping (exact, not analogy):

* PE  ->  DP worker (or serve lane);  local virtual time tau_k = committed
  work (virtual seconds of useful step time);
* Eq. (3) moving window  ->  bounded staleness: worker k may commit a new
  contribution only while ``tau_k <= delta + GVT``, GVT = min_j tau_j;
* Δ = 0   -> fully synchronous SGD (lockstep all-reduce);
  Δ = inf -> unbounded asynchrony (hogwild-style);
* GVT is simultaneously the *consistent checkpoint frontier*: all work with
  virtual time <= GVT is globally committed, which is what makes the
  measurement phase (metrics, checkpoints) scalable — the paper's central
  scalability argument, applied to training.

Because DP workers have no nearest-neighbor causality constraint, the
scheduler is the paper's Δ-constrained *random-deposition* limit (Sec. IV.A):
its steady-state utilization is predicted by the paper's own fit
``core.theory.u_rd(delta)`` — verified in tests/test_delta_sync.py.  That
curve is exactly the capacity-planning chart for a cluster with straggler
spread ~ Exp(1): pick Δ to trade progress-rate bound against memory bound.

The Eq. (3) predicate is not duplicated here: the gate is the shared
``repro.service.scheduler.window_admission`` helper — the same one the
sweep service uses for requester fairness and ``repro.serve`` uses (via
this scheduler) for decode-lane admission.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..service.scheduler import window_admission


@dataclasses.dataclass
class DeltaSyncConfig:
    n_workers: int
    delta: float = 4.0            # window, in units of mean step time
    seed: int = 0


class DeltaScheduler:
    """Host-side Δ-window scheduler over DP workers (numpy; O(L) per round).

    Each round, every eligible worker attempts one unit of work whose
    duration is supplied by the caller (measured wall-clock of its last step,
    or sampled Exp(1) in simulation).  Blocked workers idle — exactly the
    conservative update rule with the window constraint and no ring rule.
    """

    def __init__(self, cfg: DeltaSyncConfig):
        self.cfg = cfg
        self.tau = np.zeros(cfg.n_workers, dtype=np.float64)
        self._rng = np.random.default_rng(cfg.seed)
        self.rounds = 0
        self.committed = 0
        self.attempted = 0

    # ---- core update rule ----
    def offer(self, durations=None) -> np.ndarray:
        """One parallel round.  Returns bool mask of workers that committed.

        durations: per-worker step durations for this round (default Exp(1)).
        """
        cfg = self.cfg
        if durations is None:
            durations = self._rng.exponential(1.0, cfg.n_workers)
        durations = np.asarray(durations, dtype=np.float64)
        gvt = self.tau.min()
        # Eq. (3), RD limit — the one shared window predicate
        allowed = window_admission(self.tau, cfg.delta, gvt)
        self.tau = np.where(allowed, self.tau + durations, self.tau)
        self.rounds += 1
        self.committed += int(allowed.sum())
        self.attempted += cfg.n_workers
        return allowed

    # ---- observables ----
    @property
    def gvt(self) -> float:
        """Global virtual time == consistent checkpoint frontier."""
        return float(self.tau.min())

    @property
    def utilization(self) -> float:
        return self.committed / max(self.attempted, 1)

    @property
    def spread(self) -> float:
        """Horizon width — bounded by Δ + O(max step) by construction."""
        return float(self.tau.max() - self.tau.min())

    def staleness(self) -> np.ndarray:
        """Per-worker staleness tau_k - GVT; invariant: <= Δ + last step."""
        return self.tau - self.tau.min()

    def checkpoint_due(self, last_frontier: float, interval: float) -> bool:
        """True when the GVT has advanced past the next checkpoint frontier."""
        return self.gvt >= last_frontier + interval


def predicted_utilization(delta: float) -> float:
    """Paper Eq. (A.1): capacity-planning estimate for Exp(1) step times."""
    from ..core.theory import u_rd
    return float(u_rd(delta))


def gated_microbatch_weights(scheduler: DeltaScheduler, durations=None):
    """One round -> per-worker gradient weights for the lockstep emulation.

    In the single-program training loop we emulate the bounded-async cluster:
    each DP shard is a virtual worker; shards whose window rule blocks them
    this round contribute zero weight (their microbatch is deferred), and the
    loss is renormalized over committed workers.  Returns (weights, mask).
    """
    mask = scheduler.offer(durations)
    n = mask.sum()
    w = mask.astype(np.float64)
    if n > 0:
        w = w * (len(mask) / n)     # keep the gradient an unbiased average
    return w, mask

"""Finite-size scaling analysis: extrapolations and exponent estimation.

Implements the paper's data-analysis machinery:

* Krug-Meakin extrapolation, Eq. (8):   u_L = u_inf + c / L^{2(1-alpha)}
* rational-function interpolation in 1/L, Eq. (10), with model selection
  over the numerator/denominator degrees (K_n, K_d);
* growth exponent beta from <w^2(t)> ~ t^{2 beta}  (Eq. 6);
* roughness exponent alpha from <w^2>_sat ~ L^{2 alpha}  (Eqs. 7, 9).

Pure numpy — this is host-side analysis of device-produced series.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np


@dataclasses.dataclass
class Extrapolation:
    """Result of an L -> inf fit: the limit, fit coefficients, residual."""

    u_inf: float
    coeffs: dict
    residual: float
    model: str


def krug_meakin_extrapolate(Ls, uLs, alpha: float = 0.5) -> Extrapolation:
    """Least-squares fit of u_L = u_inf + c * L^{-2(1-alpha)} (Eq. 8)."""
    L = np.asarray(Ls, dtype=np.float64)
    u = np.asarray(uLs, dtype=np.float64)
    x = L ** (-2.0 * (1.0 - alpha))
    A = np.stack([np.ones_like(x), x], axis=1)
    sol, res, *_ = np.linalg.lstsq(A, u, rcond=None)
    resid = float(np.sqrt(np.mean((A @ sol - u) ** 2)))
    return Extrapolation(
        u_inf=float(sol[0]),
        coeffs={"const": float(sol[1]), "alpha": alpha},
        residual=resid,
        model=f"krug-meakin(alpha={alpha})",
    )


def _rational_design(x, u, kn, kd):
    """Linear system for u * (1 + sum b_k x^k) = sum_{k<=kn} a_k x^k.

    Unknowns [a_0..a_kn, b_1..b_kd]; row i:
      sum_k a_k x_i^k - u_i * sum_k b_k x_i^k = u_i.
    """
    cols = [x**k for k in range(kn + 1)]
    cols += [-u * x**k for k in range(1, kd + 1)]
    return np.stack(cols, axis=1)


def rational_extrapolate(Ls, uLs, max_kn: int = 3, max_kd: int = 3) -> Extrapolation:
    """Eq. (10): rational interpolation of u(1/L); extrapolates to a_0 = u_inf.

    Selects (K_n, K_d) by leave-one-out cross-validation as the paper's
    "best set of interpolation coefficients" criterion.
    """
    L = np.asarray(Ls, dtype=np.float64)
    u = np.asarray(uLs, dtype=np.float64)
    x = 1.0 / L
    n = len(x)
    best = None
    for kn, kd in itertools.product(range(1, max_kn + 1), range(0, max_kd + 1)):
        if kn + kd + 1 >= n:  # keep the fit over-determined
            continue
        A = _rational_design(x, u, kn, kd)
        # leave-one-out CV
        errs = []
        ok = True
        for i in range(n):
            mask = np.arange(n) != i
            try:
                sol, *_ = np.linalg.lstsq(A[mask], u[mask], rcond=None)
            except np.linalg.LinAlgError:
                ok = False
                break
            num = sum(sol[k] * x[i] ** k for k in range(kn + 1))
            den = 1.0 + sum(sol[kn + k] * x[i] ** k for k in range(1, kd + 1))
            if abs(den) < 1e-9:
                ok = False
                break
            errs.append((num / den - u[i]) ** 2)
        if not ok:
            continue
        cv = float(np.sqrt(np.mean(errs)))
        sol, *_ = np.linalg.lstsq(A, u, rcond=None)
        a0 = float(sol[0])
        if not (0.0 <= a0 <= 1.0):  # utilization must be physical
            continue
        if best is None or cv < best[0]:
            best = (cv, kn, kd, sol, a0)
    if best is None:
        # fall back to Krug-Meakin
        return krug_meakin_extrapolate(Ls, uLs)
    cv, kn, kd, sol, a0 = best
    return Extrapolation(
        u_inf=a0,
        coeffs={"a": sol[: kn + 1].tolist(), "b": sol[kn + 1 :].tolist()},
        residual=cv,
        model=f"rational(Kn={kn},Kd={kd})",
    )


def fit_power_law(t, y, t_min=None, t_max=None):
    """Log-log least-squares slope of y ~ t^slope over [t_min, t_max].

    Returns (slope, intercept, rms_residual_in_log_space).
    """
    t = np.asarray(t, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    m = (t > 0) & (y > 0)
    if t_min is not None:
        m &= t >= t_min
    if t_max is not None:
        m &= t <= t_max
    lt, ly = np.log(t[m]), np.log(y[m])
    A = np.stack([lt, np.ones_like(lt)], axis=1)
    sol, *_ = np.linalg.lstsq(A, ly, rcond=None)
    resid = float(np.sqrt(np.mean((A @ sol - ly) ** 2)))
    return float(sol[0]), float(sol[1]), resid


def growth_exponent(t, w2, fit_lo_frac=0.02, fit_hi_frac=0.25):
    """beta from <w^2(t)> ~ t^{2 beta} in the growth regime (Eq. 6).

    The fit window is a fraction of the pre-saturation range: by default
    [2%, 25%] of the series length, which sits inside the power-law regime
    for the sizes used in the paper's Fig. 4.
    """
    t = np.asarray(t, dtype=np.float64)
    n = len(t)
    lo, hi = max(2, int(n * fit_lo_frac)), max(4, int(n * fit_hi_frac))
    slope, _, resid = fit_power_law(t[lo:hi], np.asarray(w2)[lo:hi])
    return slope / 2.0, resid


def roughness_exponent(Ls, w2_sat):
    """alpha from <w^2>_sat ~ L^{2 alpha} (Eqs. 7, 9)."""
    slope, _, resid = fit_power_law(Ls, w2_sat)
    return slope / 2.0, resid


def saturation_width(w2_series, tail_frac=0.25):
    """Mean of the last ``tail_frac`` of the series (the plateau value)."""
    w2 = np.asarray(w2_series, dtype=np.float64)
    k = max(1, int(len(w2) * tail_frac))
    return float(np.mean(w2[-k:]))

"""Ensemble orchestration: steady-state sweeps over (L, N_V, Δ).

Host-side drivers around the jitted scan kernels in ``horizon``.  These are
what the paper calls "simulations of the simulations": each call simulates an
ensemble of independent PDES rings and extracts configurational averages.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import numpy as np

from . import horizon
from .horizon import PDESConfig
from ..obs.trace import span as _span


def _sync_if_traced(sp, tree) -> None:
    """Await device work inside a live span (honest phase attribution).

    Inert when no ambient tracer is installed, so untraced runs keep
    JAX's async dispatch; values are identical either way.
    """
    if sp is not None:
        jax.block_until_ready(tree)


@dataclasses.dataclass
class SteadyState:
    """Time- and ensemble-averaged steady-state observables."""

    cfg: PDESConfig
    n_trials: int
    burn_in_steps: int
    measure_steps: int
    utilization: float
    utilization_err: float
    w: float          # <w> = <sqrt(w2)>  (ensemble avg of per-trial widths)
    w2: float         # <w^2>
    wa: float         # <w_a>
    rate: float       # GVT growth rate per parallel step


def default_burn_in(cfg: PDESConfig) -> int:
    """Heuristic burn-in long enough to pass the crossover.

    Unconstrained KPZ: t_x ~ L^{3/2}; constrained: saturation at t_p = O(Δ·N_V)
    (width reaches ~Δ after ~Δ mean increments, each taking ~N_V picks to hit
    a border).  We take a safety factor over both.
    """
    if math.isinf(cfg.delta):
        t = 4.0 * (cfg.L ** 1.5)
    else:
        t = 60.0 * max(cfg.delta, 1.0) * max(1.0, math.sqrt(cfg.n_v)) + 2.0 * cfg.L
    return int(min(max(t, 200), 2_000_000))


def steady_state(
    cfg: PDESConfig,
    *,
    n_trials: int = 64,
    seed: int = 0,
    burn_in_steps: int | None = None,
    measure_steps: int | None = None,
    backend: str | None = None,
    engine_opts: dict | None = None,
) -> SteadyState:
    """Burn in, then time-average StepStats over ``measure_steps``.

    ``backend=None`` keeps the legacy jax.random-keyed ``horizon`` scan
    (trajectories identical to prior releases); any engine backend name
    ("reference", "pallas", "pallas_multistep", "sharded") routes through
    ``PDESEngine`` on the counter event stream — statistically equivalent,
    and the fused backends are the fast path at scale.  ``engine_opts`` is
    forwarded to the ``PDESEngine`` constructor (window, k_fuse, mesh, ...).
    """
    if burn_in_steps is None:
        burn_in_steps = default_burn_in(cfg)
    if measure_steps is None:
        measure_steps = max(200, burn_in_steps // 4)
    point = {"L": cfg.L, "n_v": cfg.n_v, "rows": n_trials}
    if backend is None:
        key = jax.random.key(seed)
        k_burn, k_meas = jax.random.split(key)
        state = horizon.init_state(cfg, n_trials)
        with _span("burn", args=dict(point, steps=burn_in_steps)) as sp:
            state = horizon.burn_in(state, k_burn, cfg, burn_in_steps)
            _sync_if_traced(sp, state)
        g0 = np.asarray(state.offset)  # GVT at measurement start (tau rebased)
        with _span("measure", args=dict(point, steps=measure_steps)) as sp:
            state, stats = horizon.run_mean(state, k_meas, cfg,
                                            measure_steps)
            _sync_if_traced(sp, stats)
    else:
        from .engine import PDESEngine
        eng = PDESEngine(cfg, backend=backend, **(engine_opts or {}))
        with _span("burn", args=dict(point, steps=burn_in_steps)) as sp:
            state = eng.burn_in(eng.init(n_trials), seed, burn_in_steps)
            _sync_if_traced(sp, state)
        g0 = np.asarray(state.offset) + np.asarray(state.tau).min(axis=-1)
        with _span("measure", args=dict(point, steps=measure_steps)) as sp:
            state, stats = eng.run_mean(state, seed, measure_steps)
            _sync_if_traced(sp, stats)
    with _span("reduce", args=point):
        u = np.asarray(stats.utilization)
        w2 = np.asarray(stats.w2)
        g1 = np.asarray(state.offset) + np.asarray(state.tau).min(axis=-1)
    return SteadyState(
        cfg=cfg,
        n_trials=n_trials,
        burn_in_steps=burn_in_steps,
        measure_steps=measure_steps,
        utilization=float(u.mean()),
        utilization_err=float(u.std(ddof=1) / np.sqrt(n_trials)),
        w=float(np.sqrt(w2).mean()),
        w2=float(w2.mean()),
        wa=float(np.asarray(stats.wa).mean()),
        rate=float((g1 - g0).mean() / measure_steps),
    )


def steady_state_sweep(
    cfg: PDESConfig,
    deltas: Sequence[float],
    *,
    n_trials: int = 64,
    seed: int = 0,
    burn_in_steps: int | None = None,
    measure_steps: int | None = None,
    backend: str = "reference",
    engine_opts: dict | None = None,
) -> list[SteadyState]:
    """Per-Δ steady states from ONE batched engine pass (window-sweep path).

    Thin ``SteadyState`` adapter over ``repro.experiments``: the Δ axis
    rides on the ensemble axis, so all ``len(deltas) * n_trials``
    trajectories advance together instead of looping ``steady_state`` per
    Δ.  ``cfg.delta`` is ignored; each returned ``SteadyState`` carries its
    own ``cfg`` with the row's Δ.  The whole recorded measurement span is
    averaged (``steady_frac=1.0``), matching the ``steady_state``
    convention; ``rate`` is the least-squares GVT slope of
    ``measurement.progress_rate`` rather than the endpoint quotient.

    ``engine_opts`` accepts the engine options a batched sweep supports —
    ``window``, ``k_fuse``, and (for ``backend="sharded"``) ``mesh`` /
    ``dist``, which route to ``experiments.sweep.run_window_sweep``'s mesh
    execution path.  ``steady_state``'s remaining engine options
    (``block_b``/``interpret``: not spec-level) are rejected explicitly
    rather than silently dropped.
    """
    from ..experiments.sweep import WindowSweep, run_window_sweep
    if burn_in_steps is None:
        burn_in_steps = max(
            default_burn_in(dataclasses.replace(cfg, delta=float(d)))
            for d in deltas)
    if measure_steps is None:
        measure_steps = max(200, burn_in_steps // 4)
    opts = dict(engine_opts or {})
    mesh = opts.pop("mesh", None)
    dist = opts.pop("dist", None)
    unsupported = sorted(set(opts) - {"window", "k_fuse"})
    if unsupported:
        raise ValueError(
            f"steady_state_sweep supports engine_opts 'window', 'k_fuse', "
            f"'mesh' and 'dist' only; got {unsupported}")
    spec = WindowSweep(
        Ls=(cfg.L,), n_vs=(cfg.n_v,), deltas=tuple(float(d) for d in deltas),
        replicas=n_trials, n_steps=measure_steps, burn_in=burn_in_steps,
        backend=backend, rd_mode=cfg.rd_mode,
        border_both=cfg.border_both, steady_frac=1.0, seed=seed, **opts)
    result = run_window_sweep(spec, mesh=mesh, dist=dist)
    out = []
    for d in deltas:
        (rec,) = result.select(delta=float(d))
        out.append(SteadyState(
            cfg=dataclasses.replace(cfg, delta=float(d)),
            n_trials=n_trials,
            burn_in_steps=burn_in_steps,
            measure_steps=measure_steps,
            utilization=rec.u,
            utilization_err=rec.u_err,
            w=rec.w,
            w2=rec.w2,
            wa=rec.wa,
            rate=rec.rate,
        ))
    return out


def utilization_vs_L(
    Ls: Sequence[int],
    *,
    n_v: int = 1,
    delta: float = math.inf,
    rd_mode: bool = False,
    n_trials: int = 64,
    seed: int = 0,
    burn_in_steps: int | None = None,
    measure_steps: int | None = None,
    backend: str | None = None,
    engine_opts: dict | None = None,
):
    """Steady-state utilization for a range of ring sizes (Figs. 2, 5)."""
    out = []
    for i, L in enumerate(Ls):
        cfg = PDESConfig(L=int(L), n_v=n_v, delta=delta, rd_mode=rd_mode)
        out.append(
            steady_state(
                cfg,
                n_trials=n_trials,
                seed=seed + i,
                burn_in_steps=burn_in_steps,
                measure_steps=measure_steps,
                backend=backend,
                engine_opts=engine_opts,
            )
        )
    return out


def width_evolution(
    cfg: PDESConfig,
    *,
    n_steps: int,
    n_trials: int = 64,
    seed: int = 0,
    backend: str | None = None,
    engine_opts: dict | None = None,
):
    """Full <w(t)>, <w_a(t)>, <u(t)> series (Figs. 2, 4, 8).

    Returns dict of numpy arrays with leading time axis.  ``backend`` routes
    through ``PDESEngine`` exactly as in ``steady_state``.
    """
    with _span("measure", args={"L": cfg.L, "n_v": cfg.n_v,
                                "rows": n_trials, "steps": n_steps}) as sp:
        if backend is None:
            key = jax.random.key(seed)
            state = horizon.init_state(cfg, n_trials)
            _, stats = horizon.run(state, key, cfg, n_steps)
        else:
            from .engine import PDESEngine
            eng = PDESEngine(cfg, backend=backend, **(engine_opts or {}))
            _, stats = eng.run(eng.init(n_trials), seed, n_steps)
        _sync_if_traced(sp, stats)
    w2 = np.asarray(stats.w2)
    return {
        "t": np.arange(1, n_steps + 1),
        "u": np.asarray(stats.utilization).mean(axis=1),
        "w": np.sqrt(w2).mean(axis=1),
        "w2": w2.mean(axis=1),
        "wa": np.asarray(stats.wa).mean(axis=1),
        "gvt": np.asarray(stats.gvt).mean(axis=1),
        "max_dev": np.asarray(stats.max_dev).mean(axis=1),
        "min_dev": np.asarray(stats.min_dev).mean(axis=1),
    }

"""Counter-based event streams for shard-local halo regeneration.

The comm-avoiding distributed runtime (DESIGN.md B4) lets a shard *re-simulate*
its neighbors' boundary PEs instead of receiving their updates each step.
That requires every shard to be able to generate the event bits of any
(trial, step, pe) coordinate locally and deterministically — a counter-based
generator indexed by global coordinates, not a stateful stream.

``counter_bits`` implements a murmur3-finalizer-based 32-bit hash over
(seed, step, trial, pe, word).  It is not cryptographic, but passes the
statistical demands of this physics (exponential increments, uniform site
picks) — verified against jax.random moments in tests/test_properties.py.

All constants are *numpy* uint32 scalars (not jnp arrays) so ``counter_words``
can run **inside a Pallas kernel body**: kernel functions may not capture
traced constants, and np scalars embed as literals.  The multistep engine
backend exploits this to generate its event stream in VMEM — no bits array
ever touches HBM (kernels/pdes_multistep.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

_C1 = np.uint32(0x85EBCA6B)
_C2 = np.uint32(0xC2B2AE35)
_GOLDEN = np.uint32(0x9E3779B9)
_STEP_C = np.uint32(0x27D4EB2F)
_TRIAL_C = np.uint32(0x165667B1)
_PE_C = np.uint32(0xD3A2646C)
_W0_C = np.uint32(0x68E31DA4)
_W1_C = np.uint32(0xB5297A4D)


def _mix(h: jax.Array) -> jax.Array:
    """murmur3 fmix32: full-avalanche 32-bit finalizer."""
    h = h ^ (h >> np.uint32(16))
    h = h * _C1
    h = h ^ (h >> np.uint32(13))
    h = h * _C2
    h = h ^ (h >> np.uint32(16))
    return h


def counter_words(
    seed: jax.Array,
    step: jax.Array,
    trial_idx: jax.Array,
    pe_idx: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """The two uint32 event words for global coordinates, unstacked.

    All inputs must already be uint32 (arrays broadcast against each other).
    Kernel-safe: plain uint32 arithmetic with literal constants, so Pallas
    bodies can call it on ``broadcasted_iota`` index planes and a scalar
    (seed, step) prefetched from SMEM/VMEM.
    """
    # sequential absorb rounds: each input is decorrelated by a full mix
    h = _mix(seed ^ _GOLDEN)
    h = _mix(h ^ (step * _STEP_C))
    h = _mix(h ^ (trial_idx * _TRIAL_C))
    h = _mix(h ^ (pe_idx * _PE_C))
    w0 = _mix(h ^ _W0_C)
    w1 = _mix(h ^ _W1_C)
    return w0, w1


def counter_bits(
    seed: int | jax.Array,
    step: jax.Array,
    trial_idx: jax.Array,
    pe_idx: jax.Array,
) -> jax.Array:
    """uint32 event bits for global coordinates; shape broadcast(trial, pe) + (2,).

    Args:
      seed: scalar int seed.
      step: scalar int32 parallel step t.
      trial_idx: (B, 1) or broadcastable global trial indices.
      pe_idx: (1, L) or broadcastable global PE indices.

    Returns: uint32 array of shape broadcast + (2,), matching the layout of
      ``horizon.event_bits`` output (word 0 -> site pick, word 1 -> eta).
    """
    w0, w1 = counter_words(
        jnp.uint32(seed),
        step.astype(jnp.uint32),
        trial_idx.astype(jnp.uint32),
        pe_idx.astype(jnp.uint32),
    )
    return jnp.stack(jnp.broadcast_arrays(w0, w1), axis=-1)


def counter_bits_block(
    seed: int | jax.Array,
    step: jax.Array,
    b0: jax.Array,
    l0: jax.Array,
    n_b: int,
    n_l: int,
) -> jax.Array:
    """Convenience: bits for the block [b0, b0+n_b) x [l0, l0+n_l) -> (n_b, n_l, 2).

    ``b0`` is either a scalar (rows consume the contiguous stream slice
    ``[b0, b0 + n_b)``) or an ``(n_b,)`` vector of *per-row* global trial
    indices — the coalesced-batch form used by ``repro.service``, where rows
    packed from different requests address arbitrary (possibly duplicate)
    stream coordinates.  A vector ``b0 = scalar + arange(n_b)`` is
    bit-identical to the scalar form.
    """
    if getattr(b0, "ndim", 0) == 1:
        bi = jnp.asarray(b0, jnp.int32)[:, None]
    else:
        bi = b0 + jnp.arange(n_b, dtype=jnp.int32)[:, None]
    li = l0 + jnp.arange(n_l, dtype=jnp.int32)[None, :]
    return counter_bits(seed, step, bi, li)

"""Sharded PDES runtime: the paper's algorithm on a TPU mesh via shard_map.

Two execution modes, both conservative (never violate causality):

* ``exact`` — paper-faithful: every parallel step does a 2-column halo
  exchange (``collective-permute`` along the ring axis) and, when the window
  is finite, an exact GVT ``all-reduce(min)``.  This is Eq. (1) + Eq. (3)
  verbatim.
* ``commavoid`` — beyond-paper (DESIGN.md B3+B4): per chunk of K steps, one
  K-wide halo exchange, one GVT all-reduce; shards *redundantly re-simulate*
  the K boundary PEs of each neighbor using the counter-based event stream
  (events.py), and the window uses the chunk-start (stale) GVT.  Because GVT
  is non-decreasing, the stale window is a subset of the exact window: the
  scheme remains conservative, and the collective+message count drops K-fold.
  The *measured utilization cost* of the staleness is quantified with this
  very simulator in EXPERIMENTS.md §Perf.

Ensemble trials shard over the ``data`` (and optionally ``pod``) axes;
the ring of L PEs shards over the ``model`` axis.  Statistics are
accumulated shard-locally per step and combined with a single batched
all-reduce per chunk — the measurement-phase pattern whose scalability the
Δ-window guarantees (the paper's central point).

**Window sweeps** ride the same layout: the per-row Δ column of a batched
sweep (``PDESEngine.init_sweep``) shards over the ensemble axes exactly
like the tau rows, so every shard sees its own rows' window widths and the
guard ``tau <= delta + GVT`` applies row-wise with no extra communication.
``trial_base`` offsets the counter event stream so that global row ``r``
consumes stream index ``trial_base + r`` on every layout — which is what
makes a sharded sweep bit-identical to the single-device serial per-Δ loop
(tests/test_sharded_sweep.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import axis_size, shard_map
from .events import counter_bits_block
from .horizon import PDESConfig, decode_words, conservative_update


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """How the PDES ensemble maps onto the device mesh."""

    ens_axes: tuple[str, ...] = ("data",)
    ring_axis: str = "model"
    mode: str = "exact"          # "exact" | "commavoid"
    k_chunk: int = 16            # steps per chunk (halo width in commavoid)

    def __post_init__(self):
        if self.mode not in ("exact", "commavoid"):
            raise ValueError(self.mode)
        if self.k_chunk < 1:
            raise ValueError("k_chunk must be >= 1")


# ---------------------------------------------------------------------------
# shard-local step math (shared by both modes and the host reference)
# ---------------------------------------------------------------------------


def _update_haloed(tau_h, bits, gvt, cfg: PDESConfig, delta=None):
    """One step on a haloed strip: tau_h (B, W + 2) -> (tau_next (B, W), update).

    Thin adapter over the shared update core in ``horizon`` (same code path
    as the reference scan and the Pallas kernels, so parity is structural).
    ``delta=None`` applies the static ``cfg.delta``; a ``(B, 1)`` array is
    the per-row window column of a batched sweep.
    """
    tau = tau_h[:, 1:-1]
    is_left, is_right, eta = decode_words(
        bits[..., 0], bits[..., 1], cfg.n_v, tau_h.dtype)
    return conservative_update(
        tau, tau_h[:, :-2], tau_h[:, 2:], is_left, is_right, eta, gvt,
        delta=cfg.delta if delta is None else delta,
        rd_mode=cfg.rd_mode, border_both=cfg.border_both)


def _local_stats(tau, update, dtype):
    """Shard-local partial reductions; additive across ring shards except
    ``min``/``max``, which combine with ``pmin``/``pmax``."""
    return (
        jnp.sum(update.astype(dtype), axis=-1),     # ucount
        jnp.sum(tau, axis=-1),                      # sum
        jnp.sum(tau * tau, axis=-1),                # sumsq
        jnp.min(tau, axis=-1),                      # min (combine with pmin)
        jnp.max(tau, axis=-1),                      # max (combine with pmax)
    )


#: Keys of the per-step stats dict every sharded runner returns, in the
#: order ``_shard_body`` emits them.  ``wa`` is absent by design: the
#: absolute width needs the ring mean *before* the deviation reduction —
#: a second all-reduce per step that the one-collective-per-chunk layout
#: deliberately avoids (the engine reports it as NaN on this backend).
STAT_KEYS = ("u", "w2", "gvt", "mean_tau", "max_dev", "min_dev")


# ---------------------------------------------------------------------------
# sharded runner
# ---------------------------------------------------------------------------


def _multi_axis_index(axes: Sequence[str]):
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * axis_size(a) + lax.axis_index(a)
    return idx


def _shard_body(tau0, off0, comp0, seed, step_base, trial_base,
                delta_col=None, trial_col=None, *, cfg: PDESConfig,
                dist: DistConfig, n_steps: int, L_total: int):
    """Runs inside shard_map.  tau0: (B_l, L_l) local shard.

    ``off0``/``comp0`` are the carried Kahan rebasing offset (sharded like
    the trial rows) so a continued run accumulates on the exact same
    summation schedule as the single-device driver — trajectories *and*
    offsets stay bitwise comparable.  ``step_base`` offsets the counter
    event stream in time (the engine passes the carried ``SimState.step``);
    ``trial_base`` offsets it along the ensemble so row 0 of this run
    consumes global stream index ``trial_base``.  ``delta_col`` is either
    None (static ``cfg.delta`` window) or the local ``(B_l,)`` slice of the
    per-row window widths of a batched sweep.  ``trial_col`` (optional
    local ``(B_l,)`` slice, sharded like the tau rows) carries *per-row
    global* stream indices — the coalesced-batch operand of
    ``repro.service``; it overrides the scalar ``trial_base`` entirely.
    """
    dtype = tau0.dtype
    ring = dist.ring_axis
    ring_n = axis_size(ring)
    ring_i = lax.axis_index(ring)
    B_l, L_l = tau0.shape
    if trial_col is not None:
        # each shard's slice already holds its rows' global trial indices
        b0 = trial_col.astype(jnp.int32)
    else:
        b0 = trial_base + _multi_axis_index(dist.ens_axes) * B_l
    l0 = ring_i * L_l
    K = dist.k_chunk
    n_chunks = -(-n_steps // K)  # stats trimmed to n_steps by caller
    fwd = [(i, (i + 1) % ring_n) for i in range(ring_n)]   # receive from left
    bwd = [(i, (i - 1) % ring_n) for i in range(ring_n)]   # receive from right

    sweep = delta_col is not None
    delta = delta_col[:, None] if sweep else None
    # a sweep's Δ column may mix finite and inf rows, so the window base is
    # always needed; inf rows still satisfy ``tau <= inf + gvt`` identically.
    finite_window = sweep or not math.isinf(cfg.delta)

    def exact_chunk(carry, c):
        tau, off, comp = carry
        step0 = step_base + c * K

        def one(tau, s):
            bits = counter_bits_block(seed, step0 + s, b0, l0, B_l, L_l)
            lcol = lax.ppermute(tau[:, -1:], ring, perm=fwd)
            rcol = lax.ppermute(tau[:, :1], ring, perm=bwd)
            tau_h = jnp.concatenate([lcol, tau, rcol], axis=1)
            if finite_window:
                gvt = lax.pmin(jnp.min(tau, axis=-1, keepdims=True), ring)
            else:
                gvt = jnp.zeros((B_l, 1), dtype)  # unused
            tau, update = _update_haloed(tau_h, bits, gvt, cfg, delta)
            return tau, _local_stats(tau, update, dtype)

        tau, parts = lax.scan(one, tau, jnp.arange(K, dtype=jnp.int32))
        return _finish_chunk(tau, off, comp, parts)

    def commavoid_chunk(carry, c):
        tau, off, comp = carry
        step0 = step_base + c * K
        # one K-wide halo exchange + one stale GVT per chunk
        lhalo = lax.ppermute(tau[:, -K:], ring, perm=fwd)
        rhalo = lax.ppermute(tau[:, :K], ring, perm=bwd)
        tau_e = jnp.concatenate([lhalo, tau, rhalo], axis=1)   # (B_l, L_l + 2K)
        if finite_window:
            gvt = lax.pmin(jnp.min(tau, axis=-1, keepdims=True), ring)
        else:
            gvt = jnp.zeros((B_l, 1), dtype)
        pe_idx = jnp.remainder(
            l0 - K + jnp.arange(L_l + 2 * K, dtype=jnp.int32), L_total)

        rows = (b0 if b0.ndim == 1
                else b0 + jnp.arange(B_l, dtype=jnp.int32))

        def one(tau_e, s):
            from .events import counter_bits
            bits = counter_bits(seed, step0 + s, rows[:, None],
                                pe_idx[None, :])
            # non-periodic edges: edge columns turn garbage 1 cell/step; the
            # interior [K, K + L_l) stays exact for all s < K (DESIGN.md B4).
            tau_pad = jnp.concatenate(
                [tau_e[:, :1], tau_e, tau_e[:, -1:]], axis=1)
            nxt, update = _update_haloed(tau_pad, bits, gvt, cfg, delta)
            stats = _local_stats(nxt[:, K:K + L_l], update[:, K:K + L_l], dtype)
            return nxt, stats

        tau_e, parts = lax.scan(one, tau_e, jnp.arange(K, dtype=jnp.int32))
        return _finish_chunk(tau_e[:, K:K + L_l], off, comp, parts)

    def _finish_chunk(tau, off, comp, parts):
        ucount, ssum, ssq, smin, smax = parts         # each (K, B_l)
        # one batched all-reduce for the whole chunk's statistics
        tot = lax.psum(jnp.stack([ucount, ssum, ssq], axis=0), ring)
        gmin = lax.pmin(smin, ring)
        gmax = lax.pmax(smax, ring)
        u = tot[0] / L_total
        mean = tot[1] / L_total
        w2 = tot[2] / L_total - mean * mean
        gvt_abs = gmin + off[None, :]
        mean_abs = mean + off[None, :]
        # rebase once per chunk (fp32 hygiene)
        shift = lax.pmin(jnp.min(tau, axis=-1), ring)
        tau = tau - shift[:, None]
        y = shift - comp
        t = off + y
        comp = (t - off) - y
        return (tau, t, comp), (u, w2, gvt_abs, mean_abs, gmax - mean,
                                mean - gmin)

    chunk = exact_chunk if dist.mode == "exact" else commavoid_chunk
    (tau, off, comp), stats = lax.scan(
        chunk, (tau0, off0, comp0), jnp.arange(n_chunks, dtype=jnp.int32))
    stats = tuple(x.reshape(n_chunks * K, B_l) for x in stats)
    return tau, off, comp, stats


def _sharded_call(cfg: PDESConfig, mesh: Mesh, dist: DistConfig,
                  n_steps: int, sweep: bool, trial_rows: bool = False):
    """shard_map-wrapped ``_shard_body`` with specs matching its operands.

    ``sweep`` appends the ensemble-sharded per-row Δ column; ``trial_rows``
    appends the ensemble-sharded per-row trial-index column (the
    coalesced-batch operand) — both ride the same ``P(ens)`` layout as the
    tau rows.
    """
    def fn(tau0, off0, comp0, seed, step_base, trial_base, *cols):
        cols = list(cols)
        delta_col = cols.pop(0) if sweep else None
        trial_col = cols.pop(0) if trial_rows else None
        return _shard_body(tau0, off0, comp0, seed, step_base, trial_base,
                           delta_col, trial_col, cfg=cfg, dist=dist,
                           n_steps=n_steps, L_total=cfg.L)

    ens, ring = dist.ens_axes, dist.ring_axis
    in_specs = (P(ens, ring), P(ens), P(ens), P(), P(), P())
    if sweep:
        in_specs += (P(ens),)
    if trial_rows:
        in_specs += (P(ens),)
    return shard_map(
        fn,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(ens, ring), P(ens), P(ens),
                   (P(None, ens),) * len(STAT_KEYS)),
        check_rep=False,
    )


def run_sharded_state(
    cfg: PDESConfig,
    mesh: Mesh,
    *,
    n_steps: int,
    seed: int = 0,
    dist: DistConfig = DistConfig(),
    tau0,
    off0,
    comp0,
    step_base=0,
    deltas=None,
    trial_base=0,
):
    """Advance a carried state; returns (tau, offset, comp, stats dict).

    The state-threading entry point the engine uses: ``tau0`` is the rebased
    local-time array, ``off0``/``comp0`` the Kahan offset pair, all sharded
    like the trial rows.  ``deltas`` (optional ``(B,)``) is the per-row
    window column of a batched sweep and ``trial_base`` the counter-stream
    index of row 0 — together they make a sharded sweep consume exactly the
    stream slices the single-device serial loop assigns to the same rows.
    A ``(B,)`` ``trial_base`` instead assigns every row its own global
    stream index (the coalesced-batch mode of ``repro.service``); the
    vector shards over the ensemble axes like the tau rows.  Stats keys are
    :data:`STAT_KEYS`; ``gvt``/``mean_tau`` are absolute (offset included).
    """
    sweep = deltas is not None
    trial_base = jnp.asarray(trial_base, jnp.int32)
    trial_rows = trial_base.ndim == 1
    shard_fn = _sharded_call(cfg, mesh, dist, n_steps, sweep, trial_rows)
    args = [tau0, off0, comp0, jnp.uint32(seed), jnp.int32(step_base),
            jnp.int32(0) if trial_rows else trial_base]
    if sweep:
        args.append(jnp.asarray(deltas, tau0.dtype))
    if trial_rows:
        args.append(trial_base)
    tau, off, comp, stats = jax.jit(shard_fn)(*args)
    return tau, off, comp, {
        k: v[:n_steps] for k, v in zip(STAT_KEYS, stats)}


def run_sharded(
    cfg: PDESConfig,
    mesh: Mesh,
    *,
    n_trials: int,
    n_steps: int,
    seed: int = 0,
    dist: DistConfig = DistConfig(),
    dtype=jnp.float32,
    tau0=None,
    step_base=0,
    deltas=None,
    trial_base=0,
):
    """Run the sharded PDES; returns (tau_abs (B, L), stats dict (n_steps, B)).

    ``n_trials`` must divide the ensemble mesh extent product and ``cfg.L``
    the ring extent.  ``tau0``/``step_base`` let a caller continue an
    existing trajectory (rebased local times + carried step counter);
    ``deltas``/``trial_base`` run a batched window sweep (see
    :func:`run_sharded_state`).  The engine threads the Kahan offset through
    :func:`run_sharded_state` instead, which avoids this wrapper's final
    ``tau + offset`` round trip.
    """
    if tau0 is None:
        tau0 = jnp.zeros((n_trials, cfg.L), dtype=dtype)
    z = jnp.zeros((tau0.shape[0],), tau0.dtype)
    tau, off, _, stats = run_sharded_state(
        cfg, mesh, n_steps=n_steps, seed=seed, dist=dist,
        tau0=tau0, off0=z, comp0=z, step_base=step_base,
        deltas=deltas, trial_base=trial_base)
    return tau + off[:, None], stats


def lower_sharded(
    cfg: PDESConfig,
    mesh: Mesh,
    *,
    n_trials: int,
    n_steps: int,
    dist: DistConfig = DistConfig(),
    dtype=jnp.float32,
    sweep: bool = False,
):
    """Lower (no execution) for the multi-pod dry-run / roofline of the core."""
    shard_fn = _sharded_call(cfg, mesh, dist, n_steps, sweep)
    B = n_trials
    args = [jax.ShapeDtypeStruct((B, cfg.L), dtype),
            jax.ShapeDtypeStruct((B,), dtype),
            jax.ShapeDtypeStruct((B,), dtype),
            jax.ShapeDtypeStruct((), jnp.uint32),
            jax.ShapeDtypeStruct((), jnp.int32),
            jax.ShapeDtypeStruct((), jnp.int32)]
    if sweep:
        args.append(jax.ShapeDtypeStruct((B,), dtype))
    return jax.jit(shard_fn).lower(*args)


# ---------------------------------------------------------------------------
# single-device reference with the identical counter event stream
# ---------------------------------------------------------------------------


def run_reference(
    cfg: PDESConfig,
    *,
    n_trials: int,
    n_steps: int,
    seed: int = 0,
    stale_every: int | None = None,
    dtype=jnp.float32,
    deltas=None,
    trial_base=0,
):
    """Unsharded oracle for run_sharded (same counter-based event stream).

    ``stale_every=None`` reproduces mode="exact"; ``stale_every=K`` reproduces
    mode="commavoid" with k_chunk=K (window base refreshed every K steps).
    ``deltas``/``trial_base`` mirror the sweep operands of
    :func:`run_sharded_state` (per-row window column, counter-stream base).

    Returns (tau_abs (B, L), stats dict (n_steps, B)) — bitwise comparable to
    run_sharded up to reduction ordering (min/sum over shards vs. full axis).
    """
    B, L = n_trials, cfg.L
    tau = jnp.zeros((B, L), dtype=dtype)
    K = stale_every or 1
    delta = None if deltas is None else jnp.asarray(deltas, dtype)[:, None]
    b0 = jnp.int32(trial_base)

    def _one_step(carry, s):
        tau, gvt_stale = carry
        bits = counter_bits_block(jnp.uint32(seed), s, b0, jnp.int32(0), B, L)
        tau_h = jnp.concatenate([tau[:, -1:], tau, tau[:, :1]], axis=1)
        if stale_every is None:
            gvt = jnp.min(tau, axis=-1, keepdims=True)
        else:
            refresh = (s % K) == 0
            gvt = jnp.where(refresh, jnp.min(tau, axis=-1, keepdims=True), gvt_stale)
        tau, update = _update_haloed(tau_h, bits, gvt, cfg, delta)
        u = jnp.mean(update.astype(dtype), axis=-1)
        mean = jnp.mean(tau, axis=-1)
        w2 = jnp.mean(tau * tau, axis=-1) - mean * mean
        gmin = jnp.min(tau, axis=-1)
        stats = (u, w2, gmin, mean, jnp.max(tau, axis=-1) - mean, mean - gmin)
        return (tau, gvt), stats

    init = (tau, jnp.zeros((B, 1), dtype))
    (tau, _), stats = lax.scan(
        _one_step, init, jnp.arange(n_steps, dtype=jnp.int32))
    return tau, dict(zip(STAT_KEYS, stats))

"""Sharded PDES runtime: the paper's algorithm on a TPU mesh via shard_map.

Two execution modes, both conservative (never violate causality):

* ``exact`` — paper-faithful: every parallel step does a 2-column halo
  exchange (``collective-permute`` along the ring axis) and, when the window
  is finite, an exact GVT ``all-reduce(min)``.  This is Eq. (1) + Eq. (3)
  verbatim.
* ``commavoid`` — beyond-paper (DESIGN.md B3+B4): per chunk of K steps, one
  K-wide halo exchange, one GVT all-reduce; shards *redundantly re-simulate*
  the K boundary PEs of each neighbor using the counter-based event stream
  (events.py), and the window uses the chunk-start (stale) GVT.  Because GVT
  is non-decreasing, the stale window is a subset of the exact window: the
  scheme remains conservative, and the collective+message count drops K-fold.
  The *measured utilization cost* of the staleness is quantified with this
  very simulator in EXPERIMENTS.md §Perf.

Ensemble trials shard over the ``data`` (and optionally ``pod``) axes;
the ring of L PEs shards over the ``model`` axis.  Statistics are
accumulated shard-locally per step and combined with a single batched
all-reduce per chunk — the measurement-phase pattern whose scalability the
Δ-window guarantees (the paper's central point).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Sequence

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import axis_size, pcast_varying, shard_map
from .events import counter_bits_block
from .horizon import PDESConfig, decode_words, conservative_update


@dataclasses.dataclass(frozen=True)
class DistConfig:
    """How the PDES ensemble maps onto the device mesh."""

    ens_axes: tuple[str, ...] = ("data",)
    ring_axis: str = "model"
    mode: str = "exact"          # "exact" | "commavoid"
    k_chunk: int = 16            # steps per chunk (halo width in commavoid)

    def __post_init__(self):
        if self.mode not in ("exact", "commavoid"):
            raise ValueError(self.mode)
        if self.k_chunk < 1:
            raise ValueError("k_chunk must be >= 1")


# ---------------------------------------------------------------------------
# shard-local step math (shared by both modes and the host reference)
# ---------------------------------------------------------------------------


def _update_haloed(tau_h, bits, gvt, cfg: PDESConfig):
    """One step on a haloed strip: tau_h (B, W + 2) -> (tau_next (B, W), update).

    Thin adapter over the shared update core in ``horizon`` (same code path
    as the reference scan and the Pallas kernels, so parity is structural).
    """
    tau = tau_h[:, 1:-1]
    is_left, is_right, eta = decode_words(
        bits[..., 0], bits[..., 1], cfg.n_v, tau_h.dtype)
    return conservative_update(
        tau, tau_h[:, :-2], tau_h[:, 2:], is_left, is_right, eta, gvt,
        delta=cfg.delta, rd_mode=cfg.rd_mode, border_both=cfg.border_both)


def _local_stats(tau, update, dtype):
    """Shard-local partial sums; additive across ring shards (except min)."""
    return (
        jnp.sum(update.astype(dtype), axis=-1),     # ucount
        jnp.sum(tau, axis=-1),                      # sum
        jnp.sum(tau * tau, axis=-1),                # sumsq
        jnp.min(tau, axis=-1),                      # min (combine with pmin)
    )


# ---------------------------------------------------------------------------
# sharded runner
# ---------------------------------------------------------------------------


def _multi_axis_index(axes: Sequence[str]):
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * axis_size(a) + lax.axis_index(a)
    return idx


def _shard_body(tau0, seed, step_base, *, cfg: PDESConfig, dist: DistConfig,
                n_steps: int, L_total: int):
    """Runs inside shard_map.  tau0: (B_l, L_l) local shard.

    ``step_base`` offsets the counter event stream so a run can continue an
    earlier trajectory (the engine passes the carried ``SimState.step``).
    """
    dtype = tau0.dtype
    ring = dist.ring_axis
    ring_n = axis_size(ring)
    ring_i = lax.axis_index(ring)
    B_l, L_l = tau0.shape
    b0 = _multi_axis_index(dist.ens_axes) * B_l
    l0 = ring_i * L_l
    K = dist.k_chunk
    n_chunks = -(-n_steps // K)  # stats trimmed to n_steps by caller
    fwd = [(i, (i + 1) % ring_n) for i in range(ring_n)]   # receive from left
    bwd = [(i, (i - 1) % ring_n) for i in range(ring_n)]   # receive from right

    finite_window = not math.isinf(cfg.delta)

    def exact_chunk(carry, c):
        tau, off, comp = carry
        step0 = step_base + c * K

        def one(tau, s):
            bits = counter_bits_block(seed, step0 + s, b0, l0, B_l, L_l)
            lcol = lax.ppermute(tau[:, -1:], ring, perm=fwd)
            rcol = lax.ppermute(tau[:, :1], ring, perm=bwd)
            tau_h = jnp.concatenate([lcol, tau, rcol], axis=1)
            if finite_window:
                gvt = lax.pmin(jnp.min(tau, axis=-1, keepdims=True), ring)
            else:
                gvt = jnp.zeros((B_l, 1), dtype)  # unused
            tau, update = _update_haloed(tau_h, bits, gvt, cfg)
            return tau, _local_stats(tau, update, dtype)

        tau, parts = lax.scan(one, tau, jnp.arange(K, dtype=jnp.int32))
        return _finish_chunk(tau, off, comp, parts)

    def commavoid_chunk(carry, c):
        tau, off, comp = carry
        step0 = step_base + c * K
        # one K-wide halo exchange + one stale GVT per chunk
        lhalo = lax.ppermute(tau[:, -K:], ring, perm=fwd)
        rhalo = lax.ppermute(tau[:, :K], ring, perm=bwd)
        tau_e = jnp.concatenate([lhalo, tau, rhalo], axis=1)   # (B_l, L_l + 2K)
        if finite_window:
            gvt = lax.pmin(jnp.min(tau, axis=-1, keepdims=True), ring)
        else:
            gvt = jnp.zeros((B_l, 1), dtype)
        pe_idx = jnp.remainder(
            l0 - K + jnp.arange(L_l + 2 * K, dtype=jnp.int32), L_total)

        def one(tau_e, s):
            from .events import counter_bits
            bits = counter_bits(seed, step0 + s,
                                (b0 + jnp.arange(B_l, dtype=jnp.int32))[:, None],
                                pe_idx[None, :])
            # non-periodic edges: edge columns turn garbage 1 cell/step; the
            # interior [K, K + L_l) stays exact for all s < K (DESIGN.md B4).
            tau_pad = jnp.concatenate(
                [tau_e[:, :1], tau_e, tau_e[:, -1:]], axis=1)
            nxt, update = _update_haloed(tau_pad, bits, gvt, cfg)
            stats = _local_stats(nxt[:, K:K + L_l], update[:, K:K + L_l], dtype)
            return nxt, stats

        tau_e, parts = lax.scan(one, tau_e, jnp.arange(K, dtype=jnp.int32))
        return _finish_chunk(tau_e[:, K:K + L_l], off, comp, parts)

    def _finish_chunk(tau, off, comp, parts):
        ucount, ssum, ssq, smin = parts               # each (K, B_l)
        # one batched all-reduce for the whole chunk's statistics
        tot = lax.psum(jnp.stack([ucount, ssum, ssq], axis=0), ring)
        gmin = lax.pmin(smin, ring)
        u = tot[0] / L_total
        mean = tot[1] / L_total
        w2 = tot[2] / L_total - mean * mean
        gvt_abs = gmin + off[None, :]
        # rebase once per chunk (fp32 hygiene)
        shift = lax.pmin(jnp.min(tau, axis=-1), ring)
        tau = tau - shift[:, None]
        y = shift - comp
        t = off + y
        comp = (t - off) - y
        return (tau, t, comp), (u, w2, gvt_abs)

    chunk = exact_chunk if dist.mode == "exact" else commavoid_chunk
    # carry starts replicated but becomes ensemble-varying after chunk 1;
    # mark it varying up front so scan's carry types match (no-op — paired
    # with check_rep=False — on JAX versions without varying types).
    z = pcast_varying(jnp.zeros((B_l,), dtype), dist.ens_axes)
    (tau, off, comp), (u, w2, gvt) = lax.scan(
        chunk, (tau0, z, z), jnp.arange(n_chunks, dtype=jnp.int32))
    stats = tuple(x.reshape(n_chunks * K, B_l) for x in (u, w2, gvt))
    return tau, off, stats


def run_sharded(
    cfg: PDESConfig,
    mesh: Mesh,
    *,
    n_trials: int,
    n_steps: int,
    seed: int = 0,
    dist: DistConfig = DistConfig(),
    dtype=jnp.float32,
    tau0=None,
    step_base=0,
):
    """Run the sharded PDES; returns (tau_abs (B, L), stats dict (n_steps, B)).

    ``n_trials`` must divide the ensemble mesh extent product and ``cfg.L``
    the ring extent.  ``tau0``/``step_base`` let the engine continue an
    existing trajectory (rebased local times + carried step counter).
    """
    fn = functools.partial(
        _shard_body, cfg=cfg, dist=dist, n_steps=n_steps, L_total=cfg.L)
    shard_fn = shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(dist.ens_axes, dist.ring_axis), P(), P()),
        out_specs=(P(dist.ens_axes, dist.ring_axis), P(dist.ens_axes),
                   (P(None, dist.ens_axes),) * 3),
        check_rep=False,
    )
    if tau0 is None:
        tau0 = jnp.zeros((n_trials, cfg.L), dtype=dtype)
    tau, off, (u, w2, gvt) = jax.jit(shard_fn)(
        tau0, jnp.uint32(seed), jnp.int32(step_base))
    stats = {"u": u[:n_steps], "w2": w2[:n_steps], "gvt": gvt[:n_steps]}
    return tau + off[:, None], stats


def lower_sharded(
    cfg: PDESConfig,
    mesh: Mesh,
    *,
    n_trials: int,
    n_steps: int,
    dist: DistConfig = DistConfig(),
    dtype=jnp.float32,
):
    """Lower (no execution) for the multi-pod dry-run / roofline of the core."""
    fn = functools.partial(
        _shard_body, cfg=cfg, dist=dist, n_steps=n_steps, L_total=cfg.L)
    shard_fn = shard_map(
        fn,
        mesh=mesh,
        in_specs=(P(dist.ens_axes, dist.ring_axis), P(), P()),
        out_specs=(P(dist.ens_axes, dist.ring_axis), P(dist.ens_axes),
                   (P(None, dist.ens_axes),) * 3),
        check_rep=False,
    )
    tau0 = jax.ShapeDtypeStruct((n_trials, cfg.L), dtype)
    return jax.jit(shard_fn).lower(tau0, jax.ShapeDtypeStruct((), jnp.uint32),
                                   jax.ShapeDtypeStruct((), jnp.int32))


# ---------------------------------------------------------------------------
# single-device reference with the identical counter event stream
# ---------------------------------------------------------------------------


def run_reference(
    cfg: PDESConfig,
    *,
    n_trials: int,
    n_steps: int,
    seed: int = 0,
    stale_every: int | None = None,
    dtype=jnp.float32,
):
    """Unsharded oracle for run_sharded (same counter-based event stream).

    ``stale_every=None`` reproduces mode="exact"; ``stale_every=K`` reproduces
    mode="commavoid" with k_chunk=K (window base refreshed every K steps).

    Returns (tau_abs (B, L), stats dict (n_steps, B)) — bitwise comparable to
    run_sharded up to reduction ordering (min/sum over shards vs. full axis).
    """
    B, L = n_trials, cfg.L
    tau = jnp.zeros((B, L), dtype=dtype)
    K = stale_every or 1

    def one_step(carry, s):
        tau, gvt_stale = carry
        bits = counter_bits_block(jnp.uint32(seed), s, jnp.int32(0), jnp.int32(0), B, L)
        tau_h = jnp.concatenate([tau[:, -1:], tau, tau[:, :1]], axis=1)
        if stale_every is None:
            gvt = jnp.min(tau, axis=-1, keepdims=True)
        else:
            refresh = (s % K) == 0
            gvt = jnp.where(refresh, jnp.min(tau, axis=-1, keepdims=True), gvt_stale)
        tau, update = _update_haloed(tau_h, bits, gvt, cfg)
        u = jnp.mean(update.astype(dtype), axis=-1)
        mean = jnp.mean(tau, axis=-1)
        w2 = jnp.mean(tau * tau, axis=-1) - mean * mean
        return (tau, gvt), (u, w2, jnp.min(tau, axis=-1))

    init = (tau, jnp.zeros((B, 1), dtype))
    (tau, _), (u, w2, gvt) = lax.scan(
        one_step, init, jnp.arange(n_steps, dtype=jnp.int32))
    return tau, {"u": u, "w2": w2, "gvt": gvt}

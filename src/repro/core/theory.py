"""Closed-form results and fits from the paper (Appendix + Eqs. 12-14).

These are the paper's *own* parameterizations of its simulation data; we use
them as validation oracles for our reproduction (EXPERIMENTS.md C6) and as
the capacity-planning formulas exposed by the framework (DESIGN.md §3.2).
"""
from __future__ import annotations


import numpy as np

#: Steady-state utilization of the unconstrained N_V = 1 scheme in the
#: infinite-L limit, Toroczkai et al / Korniss et al (paper Sec. III.A).
U_INF_KPZ_NV1 = 0.246461

#: KPZ exponents governing the unconstrained N_V = 1 horizon (Sec. III).
KPZ_ALPHA = 0.5
KPZ_BETA = 1.0 / 3.0
#: Random-deposition growth exponent (initial phase for large N_V).
RD_BETA = 0.5


def _finite_domain(d):
    """Mask the fit formulas' singular endpoints (Δ=0, Δ=inf, NaN).

    The rational fits divide by powers of Δ: at Δ=0 both ``c/d**e`` terms
    are inf and their difference is NaN (a real invalid-subtract at extreme
    Δ, not just noise), and Δ=inf needs no formula at all.  Evaluate on a
    substituted safe value and let the caller select the analytic limit.
    """
    ok = np.isfinite(d) & (d > 0)
    return ok, np.where(ok, d, 1.0)


def _masked_limits(d, ok, val):
    """Recombine: fit where valid, analytic limits at Δ=0 / Δ=+inf.

    NaN and negative Δ stay NaN — bad inputs must surface, not read as
    full utilization.
    """
    lim = np.where(d == 0, 0.0, np.where(d == np.inf, 1.0, np.nan))
    return np.where(ok, val, lim)


def u_rd(delta, four_point: bool = True):
    """Eq. (A.1): utilization of Δ-constrained random deposition, L -> inf.

    Four-point fit: ±2% over 0 <= Δ < inf; two-point: ±2.5%.
    Limits are handled explicitly (no NaN intermediates, no warnings):
    ``u_rd(0) = 0`` (window closed) and ``u_rd(inf) = 1`` (window off).
    """
    d = np.asarray(delta, dtype=np.float64)
    if four_point:
        c3, e3, c4, e4 = 15.8, 1.07, 12.3, 1.18
    else:
        c3, e3, c4, e4 = 3.47, 0.84, 0.0, 1.0
    ok, ds = _finite_domain(d)
    # clip: utilization is physical — the four-point denominator flips sign
    # below Δ ~ 1e-10, where the fit means u = 0 anyway
    val = np.clip(1.0 / (1.0 + c3 / ds**e3 - c4 / ds**e4), 0.0, 1.0)
    return _masked_limits(d, ok, val)


def u_kpz(n_v, four_point: bool = True):
    """Eq. (A.2): utilization of the unconstrained (Δ=inf) scheme, L -> inf.

    u_kpz(1) ≈ 0.2475 (cf. the exact 24.6461%); u_kpz(inf) = 1.
    """
    n = np.asarray(n_v, dtype=np.float64)
    if four_point:
        c1, e1, c2, e2 = 2.3, 0.96, 0.74, 0.4
    else:
        c1, e1, c2, e2 = 3.0, 0.715, 0.0, 1.0
    return 1.0 / (1.0 + c1 / n**e1 + c2 / n**e2)


def p_exponent(delta, n_v=None):
    """The coupling exponent p(Δ[, N_V]) of Eq. (12).

    With ``n_v=None`` returns the simple two-point formula
    ``p = 1 / (1 + 2 / Δ^{3/4})``; otherwise the piecewise four-point fit
    (A.3) with the paper's constants.
    """
    d = np.asarray(delta, dtype=np.float64)
    ok, ds = _finite_domain(d)
    if n_v is None:
        val = 1.0 / (1.0 + 2.0 / ds**0.75)
        return _masked_limits(d, ok, val)
    n = np.asarray(n_v, dtype=np.float64)
    # piecewise constants from the Appendix
    c5 = np.where(n >= 100, 528.4, np.where(n < 10, 17.43, 5.345))
    e5 = np.where(n >= 100, 1.487, np.where(n < 10, 1.406, 0.627))
    c6 = np.where(n >= 100, 515.1, np.where(n < 10, 15.3, 0.095))
    e6 = np.where(n >= 100, 1.609, np.where(n < 10, 1.687, 0.045))
    val = np.clip(1.0 / (1.0 + c5 / ds**e5 - c6 / ds**e6), 0.0, 1.0)
    return _masked_limits(d, ok, val)


def u_composite(n_v, delta, four_point: bool = True):
    """Eq. (12): u(N_V, Δ) = u_RD(Δ) · u_KPZ(N_V)^p(Δ,N_V), L -> inf.

    ±5% relative (four-point), ±10% (two-point) per the Appendix.
    """
    n = np.asarray(n_v, dtype=np.float64)
    d = np.asarray(delta, dtype=np.float64)
    if np.any(np.isinf(d)):
        # Δ = inf → window inactive → u = u_KPZ exactly by construction.
        base = u_kpz(n, four_point)
        return np.where(np.isinf(d), base,
                        _u_composite_finite(n, d, four_point))
    return _u_composite_finite(n, d, four_point)


def _u_composite_finite(n, d, four_point):
    p = p_exponent(d, n if four_point else None)
    return u_rd(d, four_point) * u_kpz(n, four_point) ** p


def u_kpz_mean_field(n_v, delta_wait, p_wait):
    """Eq. (13): mean-field utilization of the unconstrained scheme.

    1/u - 1 = (δ - 2/N_V) p_w, valid for N_V >= 3, where δ is the mean number
    of steps a PE waits given it must inquire about a neighbor and p_w the
    probability of waiting when a border site is picked.
    """
    n = np.asarray(n_v, dtype=np.float64)
    return 1.0 / (1.0 + (delta_wait - 2.0 / n) * p_wait)


def u_window_mean_field(n_v, delta_wait, p_wait, kappa, p_delta):
    """Eq. (14): mean-field utilization in the large-Δ constrained scheme."""
    n = np.asarray(n_v, dtype=np.float64)
    denom = 1.0 + (delta_wait - 2.0 / n) * p_wait \
        + (kappa - 1.0 + (2.0 / n) * p_wait) * p_delta
    return 1.0 / denom


def krug_meakin_u(L, u_inf=U_INF_KPZ_NV1, const=0.26, alpha=KPZ_ALPHA):
    """Eq. (8): finite-size utilization for generic KPZ-like processes."""
    L = np.asarray(L, dtype=np.float64)
    return u_inf + const / L ** (2.0 * (1.0 - alpha))


def kpz_crossover_time(L, z=1.5, t0=3700.0 / 100.0**1.5):
    """t_x ~ L^z; calibrated to the paper's t_x ≈ 3700 at L = 100 (Fig. 3)."""
    return t0 * np.asarray(L, dtype=np.float64) ** z

"""Core library: Δ-window constrained conservative PDES (the paper's contribution)."""
from .horizon import (  # noqa: F401
    PDESConfig,
    SimState,
    StepStats,
    burn_in,
    decode_events,
    event_bits,
    init_state,
    measure,
    run,
    run_mean,
    step_core,
)
from .measurement import (  # noqa: F401
    GroupStats,
    extreme_fluctuations,
    group_decomposition,
    progress_rate,
    recombine_w2,
    recombine_wa,
    spread,
    width,
    width_abs,
)
from . import ensemble, scaling, theory  # noqa: F401

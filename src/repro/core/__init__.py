"""Core library: Δ-window constrained conservative PDES (the paper's contribution)."""
from .horizon import (  # noqa: F401
    PDESConfig,
    SimState,
    StepStats,
    burn_in,
    decode_events,
    event_bits,
    init_state,
    measure,
    run,
    run_mean,
    step_core,
)
from .measurement import (  # noqa: F401
    GroupStats,
    extreme_fluctuations,
    group_decomposition,
    progress_rate,
    recombine_w2,
    recombine_wa,
    spread,
    width,
    width_abs,
)
from . import ensemble, scaling, theory  # noqa: F401
# engine imports the kernel wrappers, which import back into this package's
# modules — keep it last so `horizon` is fully bound first.
from .engine import EngineConfig, PDESEngine  # noqa: F401  (isort: skip)

"""Virtual time horizon dynamics for conservative PDES with a moving Δ-window.

Implements the update rules of Kolakowska, Novotny & Korniss, PRE 67, 046703:

* short-range (conservative) causality rule, Eq. (1):
  a PE that picked a *border* site may update only if its local virtual time
  does not exceed that of the neighbor(s) adjacent to the chosen border;
* moving-window global constraint, Eq. (3):
  ``tau_k <= delta + GVT`` with ``GVT = min_k tau_k`` (the global virtual
  time).  ``delta = inf`` recovers the unconstrained scheme; ``delta = 0``
  serializes the ring;
* random-deposition (RD) mode: the causality rule is dropped entirely,
  modelling the infinite-``N_V`` limit (Sec. IV.A of the paper).

All state is dense:  ``tau`` has shape ``(B, L)`` for an ensemble of ``B``
independent rings of ``L`` processing elements.  One parallel step ``t``
is one vectorized sweep.  The event stream (site picks and Poisson time
increments) is derived from counter-based uint32 bits so that every
consumer (pure-jnp reference, Pallas kernel, sharded runtime) reproduces
bit-identical trajectories.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PDESConfig:
    """Static parameters of one PDES ensemble.

    Attributes:
      L: number of processing elements on the ring.
      n_v: number of lattice sites (operation volumes) per PE, ``N_V`` in the
        paper.  Border sites are site ``0`` (left) and site ``n_v - 1``
        (right); for ``n_v == 1`` the single site is both borders and the
        causality rule compares against *both* neighbors, exactly Eq. (1).
      delta: width of the moving window, ``inf`` disables the constraint.
      rd_mode: if True, drop the causality rule (random deposition limit —
        the paper's ``N_V -> inf`` limit; only the window rule acts).
      border_both: if True, any border pick checks both neighbors (the
        literal reading of Eq. (1) for ``n_v > 1``); default False checks
        only the neighbor adjacent to the picked border, the standard model
        used in the paper's own N_V > 1 simulations (cf. Eq. (13), where a
        border pick inquires about *its* neighboring PE).
      dtype: dtype of the virtual times.
    """

    L: int
    n_v: int = 1
    delta: float = math.inf
    rd_mode: bool = False
    border_both: bool = False
    dtype: Any = jnp.float32

    def __post_init__(self):
        if self.L < 2:
            raise ValueError(f"need at least 2 PEs, got L={self.L}")
        if self.n_v < 1:
            raise ValueError(f"need at least one site per PE, got n_v={self.n_v}")
        if not (self.delta >= 0):
            raise ValueError(f"delta must be >= 0 (or inf), got {self.delta}")


class StepStats(NamedTuple):
    """Per-step per-trial observables (each ``(B,)``)."""

    utilization: jax.Array   # fraction of PEs that updated, <u(t)> per trial
    w2: jax.Array            # surface variance, Eq. (4) (before sqrt)
    wa: jax.Array            # absolute width, Eq. (5)
    gvt: jax.Array           # global virtual time min_k tau_k (absolute)
    mean_tau: jax.Array      # mean virtual time (absolute)
    max_dev: jax.Array       # extreme fluctuation above the mean
    min_dev: jax.Array       # extreme fluctuation below the mean (>= 0)


class SimState(NamedTuple):
    """Scan carry.

    ``tau`` is kept *rebased* (GVT subtracted every step) so that float32
    resolution never degrades: the dynamics only depend on differences of
    local times, and widths are O(delta) or O(L^alpha) while absolute times
    grow without bound.  The accumulated offset is carried with Kahan
    compensation so absolute observables (GVT growth rate, mean time) stay
    accurate over millions of steps.
    """

    tau: jax.Array           # (B, L) rebased virtual times, min == 0
    offset: jax.Array        # (B,) accumulated rebasing offset (Kahan sum)
    offset_comp: jax.Array   # (B,) Kahan compensation term
    step: jax.Array          # () int32 parallel step index t


# ---------------------------------------------------------------------------
# event stream: counter-based bits -> (border flags, exponential increments)
# ---------------------------------------------------------------------------


def event_bits(key: jax.Array, step: jax.Array, shape: tuple[int, ...]) -> jax.Array:
    """uint32 event bits for one parallel step, shape ``shape + (2,)``.

    Keyed on (key, step) so owner and halo-redundant shards reproduce the
    same events (communication-avoidance, DESIGN.md B4).
    """
    k = jax.random.fold_in(key, step)
    return jax.random.bits(k, shape + (2,), dtype=jnp.uint32)


def decode_words(w0: jax.Array, w1: jax.Array, n_v: int, dtype):
    """Event decode from two uint32 words -> (is_left, is_right, eta).

    site ~ Uniform{0..n_v-1} from ``w0`` (modulo; bias < 2**-16 for the
    paper's n_v range), eta ~ Exp(1) from ``w1`` via inverse CDF.

    This is THE event decode: the reference scan, both Pallas kernel bodies,
    and the sharded runtime all call it, so every backend interprets the
    event stream identically (bit-exact trajectories by construction).
    Pure jnp on plain arrays — safe inside Pallas kernel bodies.
    """
    site = jnp.remainder(w0, jnp.uint32(n_v)).astype(jnp.int32)
    is_left = site == 0
    is_right = site == (n_v - 1)
    # uniform in (0, 1]: use the top 24 bits, then add 2^-25 to avoid log(0).
    u = (w1 >> jnp.uint32(8)).astype(dtype) * 2.0**-24
    eta = -jnp.log(u + 2.0**-25)
    return is_left, is_right, eta


def decode_events(bits: jax.Array, cfg: PDESConfig):
    """bits ``(..., 2)`` -> (is_left, is_right, eta) (see ``decode_words``)."""
    return decode_words(bits[..., 0], bits[..., 1], cfg.n_v, cfg.dtype)


def conservative_update(
    tau: jax.Array,
    left: jax.Array,
    right: jax.Array,
    is_left: jax.Array,
    is_right: jax.Array,
    eta: jax.Array,
    gvt: jax.Array,
    *,
    delta: float | jax.Array,
    rd_mode: bool = False,
    border_both: bool = False,
):
    """Causality rule Eq. (1) + window rule Eq. (3) + update, in one place.

    ``left``/``right`` are the neighbor values however the caller obtained
    them (rolls on a full ring, halo columns on a shard, VMEM-resident rolls
    inside a kernel).  ``gvt`` is the window base — exact current minimum or
    a stale/conservative bound — and is ignored when ``delta`` is inf.

    ``delta`` may be a static Python float (the single-window case; inf
    short-circuits the window rule) or a *traced array* broadcastable
    against ``tau`` — e.g. a ``(B, 1)`` per-trajectory column for batched
    window sweeps, where each ensemble row carries its own Δ.  Array rows
    holding ``inf`` recover the unconstrained rule bit-for-bit, since
    ``tau <= inf + gvt`` is identically True for finite ``gvt``.

    Returns ``(tau_next, update)``.  Pure jnp — shared by the reference
    scan (``step_core``), the Pallas kernel bodies, and the sharded runtime.
    """
    if rd_mode:
        causal_ok = jnp.ones(tau.shape, dtype=bool)
    elif border_both:
        is_border = is_left | is_right
        ok = (tau <= left) & (tau <= right)
        causal_ok = jnp.where(is_border, ok, True)
    else:
        ok_left = jnp.where(is_left, tau <= left, True)
        ok_right = jnp.where(is_right, tau <= right, True)
        causal_ok = ok_left & ok_right
    if isinstance(delta, (int, float)) and math.isinf(delta):
        window_ok = jnp.ones(tau.shape, dtype=bool)
    else:
        window_ok = tau <= delta + gvt
    update = causal_ok & window_ok
    return tau + jnp.where(update, eta, 0.0), update


# ---------------------------------------------------------------------------
# one parallel update attempt (pure, RNG-free)
# ---------------------------------------------------------------------------


def step_core(
    tau: jax.Array,
    is_left: jax.Array,
    is_right: jax.Array,
    eta: jax.Array,
    cfg: PDESConfig,
    *,
    gvt_for_window: jax.Array | None = None,
    delta_override: jax.Array | None = None,
):
    """One conservative update attempt on every PE of every trial.

    Args:
      tau: (B, L) local virtual times.
      is_left/is_right: (B, L) bool, whether the picked site is the
        left/right border site (both True when n_v == 1).
      eta: (B, L) exponential(1) candidate time increments.
      gvt_for_window: optional (B, 1)-broadcastable *stale* GVT to use in the
        window rule instead of the exact current minimum.  Because GVT is
        non-decreasing, a stale value yields a stricter window and the scheme
        stays conservative (DESIGN.md B3).
      delta_override: optional (B, 1) per-trajectory window widths replacing
        the static ``cfg.delta`` — the batched window-sweep path, where the
        Δ axis rides on the ensemble axis (``inf`` rows = unconstrained).

    Returns:
      (tau_next, update_mask, gvt) with gvt the exact current minimum
      (always computed; it is also the rebasing amount).
    """
    left_nbr = jnp.roll(tau, 1, axis=-1)    # tau_{k-1}
    right_nbr = jnp.roll(tau, -1, axis=-1)  # tau_{k+1}
    gvt = jnp.min(tau, axis=-1, keepdims=True)  # (B, 1) exact global minimum
    base = gvt if gvt_for_window is None else gvt_for_window
    delta = cfg.delta if delta_override is None else delta_override
    tau_next, update = conservative_update(
        tau, left_nbr, right_nbr, is_left, is_right, eta, base,
        delta=delta, rd_mode=cfg.rd_mode, border_both=cfg.border_both)
    return tau_next, update, gvt[..., 0]


def measure(tau: jax.Array, update: jax.Array, offset: jax.Array) -> StepStats:
    """Paper observables from one post-update state (Eqs. 4-5 + utilization)."""
    dtype = tau.dtype
    mean = jnp.mean(tau, axis=-1, keepdims=True)
    dev = tau - mean
    return StepStats(
        utilization=jnp.mean(update.astype(dtype), axis=-1),
        w2=jnp.mean(dev * dev, axis=-1),
        wa=jnp.mean(jnp.abs(dev), axis=-1),
        gvt=jnp.min(tau, axis=-1) + offset,
        mean_tau=mean[..., 0] + offset,
        max_dev=jnp.max(dev, axis=-1),
        min_dev=-jnp.min(dev, axis=-1),
    )


#: Key order of ``ring_moments`` output — load-bearing for the kernels,
#: which zip it against their pallas_call output refs.
MOMENT_KEYS = ("ucount", "min", "max", "sum", "sumsq", "sumabs")


def ring_moments(tau: jax.Array, update: jax.Array) -> dict:
    """Per-ring partial reductions of one post-update state.

    Returns the raw moments every backend records per step — ``ucount``,
    ``min``, ``max``, ``sum``, ``sumsq``, ``sumabs`` (each reduced over the
    last axis) — from which ``stats_from_moments`` rebuilds the full
    ``StepStats``.  Pure jnp, usable inside Pallas kernel bodies; ``sumabs``
    (and hence ``wa``) assumes the last axis spans a complete ring, since
    the absolute width is measured about the ring mean.
    """
    dtype = tau.dtype
    s = jnp.sum(tau, axis=-1)
    mean = s / tau.shape[-1]
    return dict(
        ucount=jnp.sum(update.astype(dtype), axis=-1),
        min=jnp.min(tau, axis=-1),
        max=jnp.max(tau, axis=-1),
        sum=s,
        sumsq=jnp.sum(tau * tau, axis=-1),
        sumabs=jnp.sum(jnp.abs(tau - mean[..., None]), axis=-1),
    )


def stats_from_moments(moments: dict, offset: jax.Array, L: int) -> StepStats:
    """Assemble ``StepStats`` from ``ring_moments`` output.

    ``offset`` is the accumulated rebasing offset, broadcastable against the
    moment arrays (e.g. ``off[None, :]`` for per-chunk ``(K, B)`` moments).
    The single place where moment post-processing lives — the engine, the
    kernel-path driver, and the benchmarks all route through it.
    """
    mean = moments["sum"] / L
    return StepStats(
        utilization=moments["ucount"] / L,
        w2=moments["sumsq"] / L - mean * mean,
        wa=moments["sumabs"] / L,
        gvt=moments["min"] + offset,
        mean_tau=mean + offset,
        max_dev=moments["max"] - mean,
        min_dev=mean - moments["min"],
    )


# ---------------------------------------------------------------------------
# scan drivers
# ---------------------------------------------------------------------------


def init_state(cfg: PDESConfig, n_trials: int) -> SimState:
    """Fully synchronized initial condition (all local clocks equal; Sec. IV.B)."""
    z = jnp.zeros((n_trials,), dtype=cfg.dtype)
    return SimState(
        tau=jnp.zeros((n_trials, cfg.L), dtype=cfg.dtype),
        offset=z,
        offset_comp=z,
        step=jnp.zeros((), dtype=jnp.int32),
    )


def _kahan_add(total, comp, x):
    y = x - comp
    t = total + y
    comp = (t - total) - y
    return t, comp


def _one_step(state: SimState, key: jax.Array, cfg: PDESConfig):
    bits = event_bits(key, state.step, state.tau.shape)
    is_left, is_right, eta = decode_events(bits, cfg)
    tau, update, gvt = step_core(state.tau, is_left, is_right, eta, cfg)
    stats = measure(tau, update, state.offset)
    # rebase so the minimum returns to zero; dynamics are shift-invariant.
    shift = jnp.min(tau, axis=-1, keepdims=True)
    tau = tau - shift
    offset, comp = _kahan_add(state.offset, state.offset_comp, shift[..., 0])
    return SimState(tau, offset, comp, state.step + 1), stats


@partial(jax.jit, static_argnames=("cfg", "n_steps"))
def run(state: SimState, key: jax.Array, cfg: PDESConfig, n_steps: int):
    """Advance ``n_steps`` parallel steps, recording StepStats per step.

    Returns (final_state, StepStats with leading time axis (n_steps, B)).
    """

    def _body(st, _):
        return _one_step(st, key, cfg)

    return jax.lax.scan(_body, state, None, length=n_steps)


@partial(jax.jit, static_argnames=("cfg", "n_steps"))
def run_mean(state: SimState, key: jax.Array, cfg: PDESConfig, n_steps: int):
    """Advance ``n_steps`` steps, returning only time-averaged stats.

    Used for steady-state estimation after burn-in: O(1) memory in n_steps.
    """

    def _body(carry, _):
        st, acc = carry
        st, stats = _one_step(st, key, cfg)
        acc = jax.tree.map(lambda a, s: a + s, acc, stats)
        return (st, acc), None

    zeros = StepStats(*(jnp.zeros((state.tau.shape[0],), state.tau.dtype)
                        for _ in StepStats._fields))
    (state, acc), _ = jax.lax.scan(_body, (state, zeros), None, length=n_steps)
    mean_stats = jax.tree.map(lambda a: a / n_steps, acc)
    return state, mean_stats


@partial(jax.jit, static_argnames=("cfg", "n_steps"))
def burn_in(state: SimState, key: jax.Array, cfg: PDESConfig, n_steps: int):
    """Advance without recording (for reaching the steady state)."""

    def _body(st, _):
        st, _ = _one_step(st, key, cfg)
        return st, None

    state, _ = jax.lax.scan(_body, state, None, length=n_steps)
    return state

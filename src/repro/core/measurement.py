"""Measurement-phase observables of the virtual time horizon.

Implements the slow/fast simplex decomposition of Sec. IV.B (Eqs. 15-18)
and extreme-fluctuation diagnostics.  All functions are pure and operate on
``tau`` of shape ``(B, L)`` (ensemble of B rings).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class GroupStats(NamedTuple):
    """Slow/fast decomposition at one step (all ``(B,)``).

    The k-th PE is *slow* if ``tau_k <= mean(tau)`` (Sec. IV.B), else *fast*.
    ``w2 = f_S w2_S + f_F w2_F`` and ``wa = f_S wa_S + f_F wa_F`` exactly
    (Eqs. 17-18): the decomposition is a convex combination — a 1-d simplex.
    """

    f_slow: jax.Array    # fraction of slow PEs
    f_fast: jax.Array    # fraction of fast PEs
    w2_slow: jax.Array   # Eq. (15), X = S
    w2_fast: jax.Array   # Eq. (15), X = F
    wa_slow: jax.Array   # Eq. (16), X = S
    wa_fast: jax.Array   # Eq. (16), X = F


def group_decomposition(tau: jax.Array) -> GroupStats:
    """Slow/fast group populations and widths of a horizon, Eqs. (15)-(16)."""
    dtype = tau.dtype
    L = tau.shape[-1]
    mean = jnp.mean(tau, axis=-1, keepdims=True)
    dev = tau - mean
    slow = (tau <= mean)
    n_slow = jnp.sum(slow, axis=-1).astype(dtype)
    n_fast = L - n_slow
    # Normalize by the group population, Eqs. (15)-(16).  Guard empty groups
    # (can only happen for f_fast at exact synchronization).
    def _group_mean(x, mask, n):
        s = jnp.sum(jnp.where(mask, x, 0), axis=-1)
        return jnp.where(n > 0, s / jnp.maximum(n, 1), 0)

    return GroupStats(
        f_slow=n_slow / L,
        f_fast=n_fast / L,
        w2_slow=_group_mean(dev * dev, slow, n_slow),
        w2_fast=_group_mean(dev * dev, ~slow, n_fast),
        wa_slow=_group_mean(jnp.abs(dev), slow, n_slow),
        wa_fast=_group_mean(jnp.abs(dev), ~slow, n_fast),
    )


def recombine_w2(g: GroupStats) -> jax.Array:
    """Eq. (17): the full variance as the convex combination of group terms."""
    return g.f_slow * g.w2_slow + g.f_fast * g.w2_fast


def recombine_wa(g: GroupStats) -> jax.Array:
    """Eq. (18)."""
    return g.f_slow * g.wa_slow + g.f_fast * g.wa_fast


def width(tau: jax.Array) -> jax.Array:
    """w = sqrt(w2), Eq. (4), per trial."""
    dev = tau - jnp.mean(tau, axis=-1, keepdims=True)
    return jnp.sqrt(jnp.mean(dev * dev, axis=-1))


def width_abs(tau: jax.Array) -> jax.Array:
    """w_a, Eq. (5), per trial."""
    dev = tau - jnp.mean(tau, axis=-1, keepdims=True)
    return jnp.mean(jnp.abs(dev), axis=-1)


def extreme_fluctuations(tau: jax.Array):
    """(above, below) extreme deviations from the mean, per trial.

    The paper (Sec. V) lists the frequency/size of extreme fluctuations as the
    third efficiency component; the Δ-window bounds both by construction.
    """
    mean = jnp.mean(tau, axis=-1, keepdims=True)
    dev = tau - mean
    return jnp.max(dev, axis=-1), -jnp.min(dev, axis=-1)


def spread(tau: jax.Array) -> jax.Array:
    """max - min of the horizon, per trial; bounded by ~Δ + O(1) increments."""
    return jnp.max(tau, axis=-1) - jnp.min(tau, axis=-1)


def progress_rate(gvt_series: jax.Array, t0: int = 0) -> jax.Array:
    """Average progress rate = growth rate of the global minimum (Sec. V).

    Args:
      gvt_series: (T, B) absolute GVT per step.
      t0: first step to include (skip the transient).
    Returns: (B,) least-squares slope d(GVT)/dt over [t0, T).
    """
    g = gvt_series[t0:]
    T = g.shape[0]
    t = jnp.arange(T, dtype=g.dtype)
    t_mean = jnp.mean(t)
    g_mean = jnp.mean(g, axis=0)
    cov = jnp.mean((t[:, None] - t_mean) * (g - g_mean), axis=0)
    var = jnp.mean((t - t_mean) ** 2)
    return cov / var


# ---------------------------------------------------------------------------
# steady-state windowing + per-Δ sweep reduction
# ---------------------------------------------------------------------------


def steady_start(n_steps: int, steady_frac: float = 0.5) -> int:
    """First step of the steady-state measurement window.

    The last ``steady_frac`` of a recorded series is treated as steady state
    (the leading part is the transient); at least one step is always kept.
    """
    if not 0.0 < steady_frac <= 1.0:
        raise ValueError(f"steady_frac must be in (0, 1], got {steady_frac}")
    return min(n_steps - 1, int(round(n_steps * (1.0 - steady_frac))))


def sweep_reduce(stats, n_windows: int, replicas: int, *,
                 steady_frac: float = 0.5) -> dict:
    """Reduce batched window-sweep StepStats to per-Δ steady-state estimates.

    The sweep lays the Δ grid on the ensemble axis (``PDESEngine.init_sweep``):
    each per-step array in ``stats`` has shape ``(T, n_windows * replicas)``
    with window ``w`` owning the row block ``[w*replicas, (w+1)*replicas)``.
    This reduces time over the steady-state window (``steady_start``) and
    then the replica axis, per window.

    Returns a dict of ``(n_windows,)`` numpy arrays:
      ``u``/``u_err``       steady-state utilization (mean, standard error),
      ``w2``/``w2_err``     surface variance ⟨w²⟩, Eq. (4),
      ``w``                 width ⟨w⟩ = ⟨sqrt(w²)⟩,
      ``wa``                absolute width, Eq. (5),
      ``spread``            ⟨max τ - min τ⟩ — the horizon extent the window
                            bounds (≤ Δ + max increment, Sec. V),
      ``rate``/``rate_err`` GVT progress rate per parallel step.
    """
    u = np.asarray(stats.utilization)
    T = u.shape[0]
    if u.shape[1] != n_windows * replicas:
        raise ValueError(f"stats rows {u.shape[1]} != n_windows*replicas "
                         f"({n_windows}*{replicas})")
    t0 = steady_start(T, steady_frac)

    def _per_window(x):                      # (T, B) -> (n_windows, replicas)
        return np.asarray(x)[t0:].mean(axis=0).reshape(n_windows, replicas)

    def _mean_err(x):
        m = x.mean(axis=1)
        e = (x.std(axis=1, ddof=1) / np.sqrt(replicas) if replicas > 1
             else np.zeros_like(m))
        return m, e

    u_w, u_e = _mean_err(_per_window(stats.utilization))
    w2_w, w2_e = _mean_err(_per_window(stats.w2))
    rate = np.asarray(progress_rate(jnp.asarray(stats.gvt), t0=t0))
    r_w, r_e = _mean_err(rate.reshape(n_windows, replicas))
    spread = _per_window(np.asarray(stats.max_dev) + np.asarray(stats.min_dev))
    return {
        "u": u_w, "u_err": u_e,
        "w2": w2_w, "w2_err": w2_e,
        "w": np.sqrt(_per_window(stats.w2)).mean(axis=1),
        "wa": _mean_err(_per_window(stats.wa))[0],
        "spread": spread.mean(axis=1),
        "rate": r_w, "rate_err": r_e,
    }

"""Unified multi-backend PDES engine: one API, four execution backends.

Every way this codebase can advance the Δ-window constrained PDES — the
pure-XLA reference scan, the fused Pallas kernels, and the shard_map
runtime — used to carry its own copy of the init/rebase/Kahan/stats logic.
``PDESEngine`` owns that logic once and dispatches the inner sweep to a
backend; all backends consume the *same counter-based event stream*
(``events.counter_words`` keyed on ``(seed, step, trial, pe)``), so
trajectories are **bit-identical across backends** and cross-backend parity
is a test (tests/test_engine.py), not a hope.

Backend matrix::

    backend            device   window modes    event stream source
    -----------------  -------  --------------  --------------------------
    reference          single   exact, stale    host counter_bits
    pallas             single   exact, stale    host counter_bits -> HBM
    pallas_multistep   single   exact only      generated in-kernel (VMEM)
    sharded            mesh     exact, stale    per-shard counter_bits

* ``window="exact"`` recomputes the global virtual time ``GVT = min_k tau_k``
  every step (the paper's Eq. (3) verbatim).
* ``window="stale"`` refreshes the window base only once per ``k_fuse``-step
  chunk.  GVT is non-decreasing, so a stale base gives a *stricter* window:
  the scheme stays conservative (DESIGN.md B3) — this is the
  communication-avoiding mode whose utilization cost the scaling studies
  sweep (cf. the desynchronization protocol study, cs/0409032).
* ``pallas_multistep`` keeps whole rings VMEM-resident for ``k_fuse`` steps
  (one ``lax.scan`` over K-step chunks drives arbitrarily long runs while
  amortizing the tau HBM round trips K-fold) and generates its event bits
  in-kernel, so no bits array ever touches HBM.  The exact GVT is a cheap
  lane-wise min in VMEM, hence exact-window only.
* ``sharded`` maps ``window="exact"``/``"stale"`` onto the ``exact``/
  ``commavoid`` modes of ``core.distributed`` (per-step vs per-chunk halo
  exchange + GVT all-reduce).  ``wa`` is returned as NaN on this backend:
  the absolute width needs the global ring mean *before* the deviation
  reduction — a second all-reduce per step that the one-collective-per-chunk
  layout deliberately avoids.  All other StepStats fields are computed from
  shard-local partial reductions; run-level parity with ``reference`` is
  covered by tests/test_distributed_pdes.py and tests/test_sharded_sweep.py.

State is the same ``SimState`` as ``horizon``: rebased ``tau`` (min == 0
after every chunk), Kahan-compensated offset, step counter.  All backends
rebase once per chunk on the identical schedule, which is what makes the
trajectories comparable bit-for-bit.

**Window sweeps** (``init_sweep`` + the ``deltas=`` kwarg): the Δ grid of a
window sweep is laid out on the ensemble axis — ``B = n_windows * replicas``
rows with a per-row Δ column fed to the backends as a *batched operand*
(array window rule in the reference scan, window base folding in the
one-step kernel, a ``(B, 1)`` VMEM column in the multistep kernel, and an
ensemble-sharded ``(B,)`` column on the ``sharded`` backend — each shard
sees exactly its own rows' window widths, no extra communication).  One
device pass advances every (Δ, replica) trajectory; ``repro.experiments``
builds the paper's full (L, N_V, Δ) studies on top of this entry point,
and ``experiments.sweep.plan_mesh_sweep`` packs ragged Δ grids onto the
mesh ensemble axes.

Example::

    from repro.core import PDESConfig
    from repro.core.engine import PDESEngine

    eng = PDESEngine(PDESConfig(L=1024, n_v=10, delta=10.0),
                     backend="pallas_multistep", k_fuse=16)
    state = eng.init(n_trials=64)
    state = eng.burn_in(state, seed=0, n_steps=512)
    state, stats = eng.run(state, seed=0, n_steps=256)   # StepStats (256, B)
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax

from . import horizon
from .events import counter_bits_block
from .horizon import PDESConfig, SimState, StepStats

BACKENDS = ("reference", "pallas", "pallas_multistep", "sharded")
WINDOWS = ("exact", "stale")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static engine parameters (hashable: used as a jit static argument).

    Attributes:
      backend: one of ``BACKENDS``.
      window: "exact" (per-step GVT) or "stale" (per-chunk GVT base).
      k_fuse: steps per chunk — the multistep fuse depth, the stale-window
        refresh period, and the rebase cadence.
      block_b: ensemble rows per kernel tile (None = auto from VMEM budget).
      interpret: run Pallas kernels in interpret mode (CPU validation).
    """

    backend: str = "reference"
    window: str = "exact"
    k_fuse: int = 16
    block_b: int | None = None
    interpret: bool = True

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"backend must be one of {BACKENDS}, "
                             f"got {self.backend!r}")
        if self.window not in WINDOWS:
            raise ValueError(f"window must be one of {WINDOWS}, "
                             f"got {self.window!r}")
        if self.k_fuse < 1:
            raise ValueError("k_fuse must be >= 1")
        if self.backend == "pallas_multistep" and self.window == "stale":
            raise ValueError(
                "pallas_multistep computes the exact GVT in-VMEM each step; "
                "use backend='pallas' or 'reference' for window='stale'")


def _auto_block_b(B: int, L: int, block_b: int | None,
                  in_kernel_bits: bool = False) -> int:
    """Kernel tile rows: shared VMEM model (kernels.tiling), divisor of B."""
    from ..kernels.tiling import pick_divisor_block, pick_vmem_block
    if block_b is None:
        return pick_vmem_block(B, L, in_kernel_bits=in_kernel_bits)
    return pick_divisor_block(B, block_b)


def _make_advance(cfg: PDESConfig, ecfg: EngineConfig, B: int, L: int):
    """Backend-specific K-step chunk advance.

    Returns ``advance(tau, step0, seed, k, delta_col, b0)`` ->
    ``(tau_k, moments (k, B))`` with ``k`` static.  ``delta_col`` is either
    None (static ``cfg.delta`` window) or a traced ``(B, 1)`` column of
    per-row window widths — the batched window-sweep operand; ``b0`` is the
    counter-stream trial coordinate: a scalar global trial index of row 0
    (rows consume ``b0 + r``) or a ``(B,)`` vector of per-row indices — the
    coalesced-batch operand of ``repro.service``, where rows packed from
    different requests address arbitrary (possibly duplicate) stream
    coordinates.  No rebasing inside — the shared driver owns that.
    """
    stale = ecfg.window == "stale"

    if ecfg.backend == "reference":

        def advance(tau, step0, seed, k, delta_col, b0):
            gvt0 = jnp.min(tau, axis=-1, keepdims=True)

            def one(tau, s):
                bits = counter_bits_block(
                    seed, s, b0, jnp.int32(0), B, L)
                is_l, is_r, eta = horizon.decode_events(bits, cfg)
                tau, update, _ = horizon.step_core(
                    tau, is_l, is_r, eta, cfg,
                    gvt_for_window=gvt0 if stale else None,
                    delta_override=delta_col)
                return tau, horizon.ring_moments(tau, update)

            return lax.scan(one, tau, step0 + jnp.arange(k, dtype=jnp.int32))

    elif ecfg.backend == "pallas":
        from ..kernels.ops import ring_halo
        from ..kernels.pdes_step import pdes_step
        bb = _auto_block_b(B, L, ecfg.block_b)

        def advance(tau, step0, seed, k, delta_col, b0):
            gvt0 = jnp.min(tau, axis=-1, keepdims=True)

            def one(tau, s):
                bits = counter_bits_block(
                    seed, s, b0, jnp.int32(0), B, L)
                gvt = gvt0 if stale else jnp.min(tau, axis=-1, keepdims=True)
                # per-row Δ folds into the window base: the kernel's rule is
                # ``tau <= delta + gvt``, so passing ``gvt + delta_col`` with
                # a static delta of 0 applies each row's own window — same
                # fp32 add, bit-identical to the static-delta path.
                if delta_col is None:
                    gvt_eff, d = gvt, cfg.delta
                else:
                    gvt_eff, d = gvt + delta_col, 0.0
                return pdes_step(
                    ring_halo(tau), bits, gvt_eff,
                    n_v=cfg.n_v, delta=d, rd_mode=cfg.rd_mode,
                    border_both=cfg.border_both, block_b=bb,
                    interpret=ecfg.interpret)

            return lax.scan(one, tau, step0 + jnp.arange(k, dtype=jnp.int32))

    elif ecfg.backend == "pallas_multistep":
        from ..kernels.pdes_multistep import pdes_multistep_counter
        bb = _auto_block_b(B, L, ecfg.block_b, in_kernel_bits=True)

        def advance(tau, step0, seed, k, delta_col, b0):
            # a (B,) b0 becomes the per-row trial column; ctr's scalar slot
            # is then unused (zeroed) — the kernel reads the column instead.
            vec = getattr(b0, "ndim", 0) == 1
            b0_scalar = jnp.uint32(0) if vec else b0.astype(jnp.uint32)
            trial_col = b0.astype(jnp.uint32)[:, None] if vec else None
            ctr = jnp.stack([
                seed.astype(jnp.uint32), step0.astype(jnp.uint32),
                b0_scalar, jnp.uint32(0)])[None, :]
            return pdes_multistep_counter(
                tau, ctr, delta_col, trial_col, k_steps=k,
                n_v=cfg.n_v, delta=cfg.delta, rd_mode=cfg.rd_mode,
                border_both=cfg.border_both, block_b=bb,
                interpret=ecfg.interpret)

    else:  # pragma: no cover - sharded handled outside the single-device jit
        raise ValueError(ecfg.backend)

    return advance


@functools.partial(jax.jit, static_argnames=("cfg", "ecfg", "n_steps", "mode"))
def _run_single(state: SimState, seed, cfg: PDESConfig, ecfg: EngineConfig,
                n_steps: int, mode: str, deltas=None, trial_base=0):
    """Shared chunked driver for the single-device backends.

    mode: "record" -> StepStats with leading (n_steps,) axis;
          "mean"   -> time-averaged StepStats (O(1) memory in n_steps);
          "burn"   -> state only (stats math dead-code-eliminated).
    deltas: optional (B,) per-row window widths (sweep mode, see ``run``).
    trial_base: counter-stream trial coordinate — scalar index of row 0,
      or a (B,) vector of per-row global trial indices (see ``run``).
    """
    B, L = state.tau.shape
    K = max(1, min(ecfg.k_fuse, n_steps))
    n_chunks, rem = divmod(n_steps, K)
    advance = _make_advance(cfg, ecfg, B, L)
    dtype = state.tau.dtype
    delta_col = None if deltas is None else deltas.astype(dtype)[:, None]
    b0 = jnp.asarray(trial_base, jnp.int32)

    def chunk(carry, k):
        tau, off, comp, step0 = carry
        tau, moments = advance(tau, step0, seed, k, delta_col, b0)
        stats = horizon.stats_from_moments(moments, off[None, :], L)
        # rebase once per chunk: identical schedule on every backend, so
        # trajectories stay bitwise comparable (fp32 hygiene per SimState).
        shift = jnp.min(tau, axis=-1)
        tau = tau - shift[:, None]
        off, comp = horizon._kahan_add(off, comp, shift)
        return (tau, off, comp, step0 + k), stats

    carry = (state.tau, state.offset, state.offset_comp, state.step)
    zeros = StepStats(*(jnp.zeros((B,), dtype) for _ in StepStats._fields))
    pieces, acc = [], zeros
    if n_chunks:
        if mode == "record":
            carry, st = lax.scan(lambda c, _: chunk(c, K), carry, None,
                                 length=n_chunks)
            pieces.append(jax.tree.map(
                lambda a: a.reshape(n_chunks * K, B), st))
        else:
            def body(c_acc, _):
                c, a = c_acc
                c, st = chunk(c, K)
                a = jax.tree.map(lambda x, s: x + jnp.sum(s, axis=0), a, st)
                return (c, a), None

            (carry, acc), _ = lax.scan(body, (carry, acc), None,
                                       length=n_chunks)
    if rem:
        carry, st = chunk(carry, rem)
        if mode == "record":
            pieces.append(st)
        else:
            acc = jax.tree.map(lambda x, s: x + jnp.sum(s, axis=0), acc, st)

    tau, off, comp, step = carry
    out_state = SimState(tau, off, comp, step)
    if mode == "burn":
        return out_state, None
    if mode == "record":
        stats = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *pieces)
    else:
        stats = jax.tree.map(lambda a: a / n_steps, acc)
    return out_state, stats


class PDESEngine:
    """One entry point for every PDES execution path (see module docstring).

    Args:
      cfg: the physics (``PDESConfig``).
      backend: one of ``BACKENDS``.
      window: "exact" | "stale" (see module docstring).
      k_fuse: chunk depth (fuse/refresh/rebase cadence).
      block_b: kernel tile rows (None = auto).
      interpret: Pallas interpret mode (CPU validation).
      mesh / dist: required/optional for ``backend="sharded"`` — the device
        mesh and ``DistConfig``.  When ``dist`` is omitted it is derived
        from ``window`` (exact -> "exact", stale -> "commavoid" with
        ``k_chunk=k_fuse``).
    """

    def __init__(self, cfg: PDESConfig, backend: str = "reference", *,
                 window: str = "exact", k_fuse: int = 16,
                 block_b: int | None = None, interpret: bool = True,
                 mesh=None, dist=None):
        self.cfg = cfg
        self.ecfg = EngineConfig(backend=backend, window=window,
                                 k_fuse=k_fuse, block_b=block_b,
                                 interpret=interpret)
        self.mesh = mesh
        self.dist = dist
        if backend == "sharded":
            if mesh is None:
                raise ValueError("backend='sharded' requires a mesh")
            if dist is None:
                from .distributed import DistConfig
                self.dist = DistConfig(
                    mode="exact" if window == "exact" else "commavoid",
                    k_chunk=k_fuse)
            elif (self.dist.mode == "exact") != (window == "exact"):
                raise ValueError(
                    f"window={window!r} conflicts with dist.mode="
                    f"{self.dist.mode!r}")

    # -- state ------------------------------------------------------------

    def init(self, n_trials: int) -> SimState:
        """Fully synchronized initial condition (all clocks equal)."""
        return horizon.init_state(self.cfg, n_trials)

    def init_sweep(self, deltas, replicas: int):
        """Per-Δ window state for a batched window sweep.

        Lays the Δ grid out on the ensemble axis: ``B = n_windows * replicas``
        rows, window ``w`` owning rows ``[w*replicas, (w+1)*replicas)`` —
        exactly the flattened form of vmapping the window state over the Δ
        axis on top of the replica batch.  Rows with ``inf`` run
        unconstrained.  Pass the returned ``deltas`` row array to ``run`` /
        ``run_mean`` / ``burn_in``; one device pass then advances all
        ``n_windows x replicas`` trajectories.

        Returns:
          (state, deltas_rows) with ``deltas_rows`` of shape ``(B,)``.
        """
        d = jnp.repeat(jnp.asarray(deltas, self.cfg.dtype), replicas)
        return self.init(int(d.shape[0])), d

    # -- drivers ----------------------------------------------------------

    def run(self, state: SimState, seed, n_steps: int, *,
            deltas=None, trial_base=0):
        """Advance ``n_steps``, recording StepStats per step (n_steps, B).

        Args:
          deltas: optional (B,) per-row window widths — the sweep mode
            (see ``init_sweep``); overrides ``cfg.delta`` row-wise.
          trial_base: global trial index of row 0 in the counter event
            stream.  A serial per-Δ loop that runs window ``w`` with
            ``trial_base=w*replicas`` consumes exactly the stream slice the
            batched sweep assigns to those rows, so the two are comparable
            bit-for-bit (tests/test_experiments.py).  A ``(B,)`` int vector
            instead assigns every row its *own* global trial index — the
            coalesced-batch mode of ``repro.service``, which packs rows
            from many requests (arbitrary, possibly duplicate, stream
            coordinates) into one pass; ``trial_base=c + arange(B)`` is
            bit-identical to the scalar ``trial_base=c``.
        """
        return self._dispatch(state, seed, n_steps, "record",
                              deltas=deltas, trial_base=trial_base)

    def run_mean(self, state: SimState, seed, n_steps: int, *,
                 deltas=None, trial_base=0):
        """Advance ``n_steps``; return only time-averaged StepStats (B,)."""
        return self._dispatch(state, seed, n_steps, "mean",
                              deltas=deltas, trial_base=trial_base)

    def burn_in(self, state: SimState, seed, n_steps: int, *,
                deltas=None, trial_base=0) -> SimState:
        """Advance without recording (reach the steady state)."""
        return self._dispatch(state, seed, n_steps, "burn",
                              deltas=deltas, trial_base=trial_base)[0]

    def _dispatch(self, state, seed, n_steps, mode, deltas=None, trial_base=0):
        if n_steps < 1:
            raise ValueError(f"n_steps must be >= 1, got {n_steps}")
        seed = jnp.uint32(seed)
        if deltas is not None:
            deltas = jnp.asarray(deltas, state.tau.dtype)
            if deltas.shape != (state.tau.shape[0],):
                raise ValueError(
                    f"deltas must have shape ({state.tau.shape[0]},) — one "
                    f"window width per ensemble row — got {deltas.shape}")
        trial_base = jnp.asarray(trial_base, jnp.int32)
        if trial_base.ndim not in (0, 1) or (
                trial_base.ndim == 1
                and trial_base.shape != (state.tau.shape[0],)):
            raise ValueError(
                f"trial_base must be a scalar or have shape "
                f"({state.tau.shape[0]},) — one stream index per ensemble "
                f"row — got {trial_base.shape}")
        if self.ecfg.backend == "sharded":
            return self._run_sharded(state, seed, n_steps, mode,
                                     deltas=deltas, trial_base=trial_base)
        return _run_single(state, seed, self.cfg, self.ecfg, n_steps, mode,
                           deltas, trial_base)

    def _run_sharded(self, state, seed, n_steps, mode, deltas=None,
                     trial_base=0):
        from . import distributed as D
        K = self.dist.k_chunk
        if n_steps % K:
            raise ValueError(
                f"sharded backend advances whole chunks: n_steps={n_steps} "
                f"must be a multiple of k_chunk={K}")
        tau, off, comp, st = D.run_sharded_state(
            self.cfg, self.mesh, n_steps=n_steps, seed=seed,
            dist=self.dist, tau0=state.tau, off0=state.offset,
            comp0=state.offset_comp, step_base=state.step,
            deltas=deltas, trial_base=trial_base)
        out_state = SimState(tau, off, comp, state.step + n_steps)
        if mode == "burn":
            return out_state, None
        # ``gvt``/``mean_tau`` come back absolute (the runtime adds the
        # carried offset chunk-by-chunk, same schedule as _run_single).
        nan = jnp.full(st["u"].shape, jnp.nan, state.tau.dtype)
        stats = StepStats(
            utilization=st["u"], w2=st["w2"], wa=nan, gvt=st["gvt"],
            mean_tau=st["mean_tau"], max_dev=st["max_dev"],
            min_dev=st["min_dev"])
        if mode == "mean":
            stats = jax.tree.map(lambda a: jnp.mean(a, axis=0), stats)
        return out_state, stats

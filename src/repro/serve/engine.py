"""Batched serving engine with Δ-window lane synchronization.

Continuous batching: B decode lanes advance token-by-token; lanes finish and
are refilled from a request queue.  The Δ-window rule (paper Eq. (3)) bounds
how far any lane's *virtual completion time* may run ahead of the slowest
lane before the engine forces a flush — bounding head-of-line blocking and
the per-lane KV/state retention, which is the serving-side version of the
measurement-phase memory bound.

The engine is backend-agnostic: it drives any model exposing
prefill/decode_step (models/model.py).

The lane gate is ``DeltaScheduler.offer``, whose admission predicate is
the shared :func:`repro.service.scheduler.window_admission` — the same
Eq. (3) rule that throttles requesters in the batched sweep service
(``repro.service``, the request/response sibling of this module).
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from ..distributed.delta_sync import DeltaScheduler, DeltaSyncConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 32


@dataclasses.dataclass
class Result:
    uid: int
    tokens: list


class ServeEngine:
    def __init__(self, model, params, *, batch_lanes: int, max_len: int,
                 delta: float = 64.0, seed: int = 0):
        self.model = model
        self.params = params
        self.lanes = batch_lanes
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.results: dict[int, Result] = {}
        self.scheduler = DeltaScheduler(
            DeltaSyncConfig(n_workers=batch_lanes, delta=delta, seed=seed))
        self._decode = jax.jit(model.decode_step)

    def submit(self, req: Request):
        self.queue.append(req)

    def _prefill_batch(self, reqs):
        S = max(len(r.prompt) for r in reqs)
        toks = np.zeros((len(reqs), S), np.int32)
        for i, r in enumerate(reqs):
            toks[i, S - len(r.prompt):] = r.prompt      # left-pad
        batch = {"tokens": jnp.asarray(toks)}
        logits, cache = jax.jit(self.model.prefill)(self.params, batch)
        return logits, cache, S

    def run(self, max_steps: int = 10_000):
        """Drain the queue; returns {uid: Result}."""
        while self.queue:
            reqs = [self.queue.popleft()
                    for _ in range(min(self.lanes, len(self.queue)))]
            logits, cache, pos0 = self._prefill_batch(reqs)
            n = len(reqs)
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
            out = [[int(tok[i, 0])] for i in range(n)]
            done = np.zeros(n, bool)
            budget = np.array([r.max_new_tokens for r in reqs])
            for step in range(min(self.max_len - pos0 - 1, max_steps)):
                # Δ-window lane gate: lanes too far ahead idle this round
                mask = self.scheduler.offer()[:n]
                logits, cache = self._decode(
                    self.params, cache, tok, jnp.int32(pos0 + step))
                nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                tok = jnp.where(jnp.asarray(mask)[:, None], nxt, tok)
                for i in range(n):
                    if mask[i] and not done[i]:
                        out[i].append(int(nxt[i, 0]))
                        if len(out[i]) >= budget[i]:
                            done[i] = True
                if done.all():
                    break
            for r, toks in zip(reqs, out):
                self.results[r.uid] = Result(r.uid, toks)
        return self.results

    @property
    def lane_utilization(self) -> float:
        return self.scheduler.utilization

"""Serving: continuous batching engine with Δ-window lane synchronization."""
from .engine import Request, Result, ServeEngine  # noqa: F401

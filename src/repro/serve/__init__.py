"""Serving: continuous batching engine with Δ-window lane synchronization.

Sibling of :mod:`repro.service` (the batched *sweep* front end): both
reuse the paper's Eq. (3) as an admission rule via the shared
:func:`repro.service.scheduler.window_admission` predicate — decode
lanes here, requester fairness there, DP workers in
``repro.distributed.delta_sync``.
"""
from ..service.scheduler import window_admission  # noqa: F401  (shared gate)
from .engine import Request, Result, ServeEngine  # noqa: F401

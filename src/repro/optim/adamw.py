"""AdamW with decoupled weight decay, global-norm clipping, mixed precision.

Plain-pytree implementation (no optax).  Optimizer-state dtype is
configurable: fp32 by default; bf16 for arctic-480b where fp32 m/v would
blow the HBM budget (config's param_dtype doubles as the opt-state dtype).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    """Linear warmup -> cosine decay to min_lr_frac * peak."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.peak_lr * jnp.minimum(warm, 1.0) * cos


def _decayable(path: str) -> bool:
    """Weight decay applies to matrices, not to norms/biases/1-d params."""
    for tag in ("scale", "bias", "A_log", "dt_bias", "'D'", "'b"):
        if tag in path:
            return False
    return True


def global_norm(tree):
    sq = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))), tree, 0.0)
    return jnp.sqrt(sq)


def init(params):
    z = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params),
            "count": jnp.zeros((), jnp.int32)}


def update(grads, opt_state, params, cfg: AdamWConfig):
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    lr = lr_schedule(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    bc1 = 1 - cfg.b1 ** count.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(kp, p, g, m, v):
        path = jax.tree_util.keystr(kp)
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + (1 - cfg.b1) * g
        v32 = v.astype(jnp.float32) * cfg.b2 + (1 - cfg.b2) * g * g
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        if _decayable(path):
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p32 = p.astype(jnp.float32) - lr * step
        return p32.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree_util.tree_map_with_path(
        upd, params, grads, opt_state["m"], opt_state["v"])
    # unzip the (p, m, v) tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out,
                         is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}

"""Optimizers and gradient utilities."""
from .adamw import AdamWConfig, global_norm, init, lr_schedule, update  # noqa: F401
from . import grad  # noqa: F401

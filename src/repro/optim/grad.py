"""Gradient utilities: int8 error-feedback compression for cross-pod
all-reduce, and explicit compressed DP reduction via shard_map.

At 1000+ nodes the pod-level (DCN) gradient all-reduce is the scarcest
bandwidth.  ``compressed_psum`` quantizes each leaf to int8 with a per-leaf
scale before the pod-axis psum and keeps the quantization residual locally
(error feedback), so the *long-run* gradient is unbiased while per-step DCN
bytes drop 4× vs f32 (2× vs bf16).  Collective-byte impact is measured in
§Perf via the dry-run HLO.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..compat import shard_map


def quantize_int8(x, *, stochastic_key=None):
    """Symmetric per-tensor int8 quantization.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(x)) + 1e-12
    scale = amax / 127.0
    y = x / scale
    if stochastic_key is not None:
        y = y + jax.random.uniform(stochastic_key, y.shape, y.dtype, -0.5, 0.5)
    q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress_leaf(g, err):
    """Error-feedback compression of one leaf: returns (q, scale, new_err)."""
    g32 = g.astype(jnp.float32) + err
    q, scale = quantize_int8(g32)
    new_err = g32 - dequantize(q, scale)
    return q, scale, new_err


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def compressed_psum(grads, err_state, axis_name: str):
    """int8 + error-feedback psum over ``axis_name`` (inside shard_map).

    Each participant quantizes (g + err) to int8, psums the int8 payload (as
    int32 accumulator) and the scales, and dequantizes with the mean scale.
    Residuals stay local.  Returns (reduced grads f32, new err_state).
    """
    n = lax.psum(1, axis_name)

    def leaf(g, e):
        q, scale, new_e = ef_compress_leaf(g, e)
        tot = lax.psum(q.astype(jnp.int32), axis_name)
        s = lax.psum(scale, axis_name) / n           # mean scale approx
        return tot.astype(jnp.float32) * s / n, new_e

    out = jax.tree_util.tree_map(leaf, grads, err_state)
    red = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    new_err = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return red, new_err


def make_compressed_dp_allreduce(mesh: Mesh, pod_axis: str = "pod"):
    """shard_map wrapper reducing grads over the pod (DCN) axis with int8 EF.

    Grads enter sharded however they are; only the pod axis is reduced.
    """

    def reduce_fn(grads, err):
        return compressed_psum(grads, err, pod_axis)

    def apply(grads, err_state):
        specs = jax.tree.map(lambda _: P(), grads)   # per-shard local view
        f = shard_map(reduce_fn, mesh=mesh,
                      in_specs=(specs, specs), out_specs=(specs, specs))
        return f(grads, err_state)

    return apply

"""Optimal window width Δ*: the paper's tuning-parameter claim, quantified.

The paper's closing argument (Sec. V): the window width Δ is a *tuning
parameter* — "for a given volume load per processor, [it] could be adjusted
to optimize the utilization so as to maximize the efficiency".  The two
sides of the trade-off, both measured by a window sweep:

* utilization u(Δ) rises monotonically with Δ (more PEs clear the window
  rule per step) and saturates at the unconstrained value;
* the horizon width w(Δ) also rises with Δ — and the width *is* the cost of
  the measurement phase: every PE must hold its state history across the
  horizon extent for state saving / data collection, so memory and
  measurement latency grow with w (that is the phase that fails to scale
  without the window).

We therefore score a window by utilization per unit width-bounded cost::

    efficiency(Δ) = u(Δ) / (1 + w(Δ))

(the 1 is the O(1) per-event compute+communication cost floor; ``w`` is the
steady-state width ⟨sqrt(w²)⟩).  Small Δ throttles u, large Δ pays
unbounded width — the maximizer Δ* is interior, which is exactly the
paper's qualitative claim and what tests/test_experiments.py asserts.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..core.horizon import PDESConfig
from .sweep import SweepResult, WindowSweep, run_window_sweep


def efficiency(u, w):
    """Utilization per unit width-bounded cost, u / (1 + w) (elementwise)."""
    return np.asarray(u, dtype=float) / (1.0 + np.asarray(w, dtype=float))


@dataclasses.dataclass(frozen=True)
class OptimalWindow:
    """The efficiency curve of one (L, N_V) grid point and its maximizer."""

    L: int
    n_v: int
    deltas: tuple[float, ...]      # sorted, as swept (inf allowed, last)
    eff: tuple[float, ...]         # efficiency per Δ, same order
    u: tuple[float, ...]
    w: tuple[float, ...]
    delta_star: float              # grid maximizer of the efficiency
    eff_star: float
    interior: bool                 # Δ* strictly inside the swept grid

    def as_dict(self) -> dict:
        """JSON-ready dict (``inf`` spelled as the string ``"inf"``)."""
        d = dataclasses.asdict(self)
        d["deltas"] = ["inf" if math.isinf(x) else x for x in self.deltas]
        for k in ("deltas", "eff", "u", "w"):
            d[k] = list(d[k])
        return d


def find_optimal_window(result: SweepResult, *, L: int,
                        n_v: int) -> OptimalWindow:
    """Locate Δ* on the swept grid of one (L, N_V) point.

    Sorts the records by Δ (inf last), computes the efficiency curve, and
    returns the grid argmax.  ``interior`` reports whether the maximum sits
    strictly between the smallest and largest swept Δ — the paper's
    qualitative prediction for any grid wide enough to bracket the
    trade-off.
    """
    recs = sorted(result.select(L=L, n_v=n_v), key=lambda r: r.delta)
    if not recs:
        raise ValueError(f"no records for L={L}, n_v={n_v}")
    deltas = tuple(r.delta for r in recs)
    u = tuple(r.u for r in recs)
    w = tuple(r.w for r in recs)
    eff = efficiency(u, w)
    i = int(np.argmax(eff))
    return OptimalWindow(
        L=L, n_v=n_v, deltas=deltas, eff=tuple(float(e) for e in eff),
        u=u, w=w, delta_star=deltas[i], eff_star=float(eff[i]),
        interior=0 < i < len(deltas) - 1)


def optimal_windows(spec_or_result: WindowSweep | SweepResult
                    ) -> list[OptimalWindow]:
    """Δ* for every (L, N_V) grid point of a sweep (running it if needed)."""
    result = (spec_or_result if isinstance(spec_or_result, SweepResult)
              else run_window_sweep(spec_or_result))
    return [find_optimal_window(result, L=int(L), n_v=int(n_v))
            for L in result.spec.Ls for n_v in result.spec.n_vs]


# ---------------------------------------------------------------------------
# adaptive Δ* refinement through the sweep service
# ---------------------------------------------------------------------------

_INV_PHI = (math.sqrt(5.0) - 1.0) / 2.0     # golden-section shrink ratio


@dataclasses.dataclass(frozen=True)
class RefinedWindow:
    """A golden-section-refined optimum of one (L, N_V) grid point.

    ``evaluations`` logs every Δ probed, in evaluation order, with its
    efficiency — the coarse grid first, then the interior golden-section
    points, then the polish re-measurement of the winner.
    """

    L: int
    n_v: int
    delta_star: float
    eff_star: float
    u_star: float
    w_star: float
    bracket: tuple[float, float]   # initial finite bracket around Δ*
    evaluations: tuple[tuple[float, float], ...]   # (Δ, efficiency)
    rounds: int                    # golden-section rounds actually run
    interior: bool                 # coarse argmax strictly inside the grid

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["evaluations"] = [list(e) for e in self.evaluations]
        d["bracket"] = list(self.bracket)
        return d


def refine_optimal_window(spec: WindowSweep, *, L=None, n_v=None,
                          rounds: int = 4, polish_steps: int | None = None,
                          service=None, mesh=None, dist=None
                          ) -> RefinedWindow:
    """Golden-section search for Δ*, issuing probes through the sweep service.

    ``spec.deltas`` is the coarse bracketing grid.  Every probe is a
    single-Δ ``WindowSweep`` submitted to a :class:`~repro.service.
    SweepService` (``service=`` to share one across calls; else a private
    one is built with ``mesh``/``dist``), so

    * all probes of a round share a ``CompatKey`` and coalesce into one
      device pass (single-Δ specs always lay their rows on trials
      ``0..replicas-1``),
    * re-probing a Δ dedups at the service layer (same fingerprint), and
    * the final polish round — the winner re-measured with ``polish_steps``
      (default ``2 * spec.n_steps``) — reuses every burned-in row from the
      service state cache (the cache key excludes ``n_steps``).

    The search runs only when the coarse argmax is interior (the paper's
    claim for a bracketing grid); a boundary argmax is returned as-is with
    ``interior=False``.  Versus sweeping a dense fixed grid, the refiner
    reaches the same Δ* to bracket tolerance in far fewer engine row-steps
    (tests/test_service.py).
    """
    from ..service import SweepService
    L = int(L if L is not None else spec.Ls[0])
    n_v = int(n_v if n_v is not None else spec.n_vs[0])
    cfg = PDESConfig(L=L, n_v=n_v, delta=math.inf, rd_mode=spec.rd_mode,
                     border_both=spec.border_both)
    burn = int(spec.burn_in_for(cfg))
    if service is None:
        service = SweepService(mesh=mesh, dist=dist)
    memo: dict[float, tuple[float, float, float]] = {}   # Δ -> (u, w, eff)
    evaluations: list[tuple[float, float]] = []

    def probe_spec(delta: float, n_steps: int) -> WindowSweep:
        return dataclasses.replace(
            spec, Ls=(L,), n_vs=(n_v,), deltas=(float(delta),),
            n_steps=int(n_steps), burn_in=burn)

    def evaluate(deltas, n_steps=spec.n_steps):
        new = [float(d) for d in deltas if float(d) not in memo]
        reqs = [service.submit(probe_spec(d, n_steps), requester="refiner")
                for d in new]
        if reqs:
            by_id = {r.request_id: r.result
                     for r in service.drain() if r.result is not None}
            for d, req in zip(new, reqs):
                rec = by_id[req.request_id].records[0]
                eff = float(efficiency(rec.u, rec.w))
                memo[d] = (float(rec.u), float(rec.w), eff)
                evaluations.append((d, eff))
        return [memo[float(d)][2] for d in deltas]

    # coarse pass: the spec's own grid, one coalesced pass
    grid = tuple(sorted(float(d) for d in spec.deltas))
    evaluate(grid)
    i = int(np.argmax([memo[d][2] for d in grid]))
    interior = 0 < i < len(grid) - 1
    finite = [d for d in grid if math.isfinite(d)]
    if not finite:
        raise ValueError("refinement needs at least one finite Δ in the grid")
    a = grid[i - 1] if i > 0 and math.isfinite(grid[i - 1]) else finite[0]
    b = grid[i + 1] if interior and math.isfinite(grid[i + 1]) else finite[-1]
    bracket = (a, b)

    done = 0
    if interior and b > a:
        c = b - _INV_PHI * (b - a)
        d = a + _INV_PHI * (b - a)
        evaluate([c, d])                      # both points, one shared pass
        for done in range(1, rounds + 1):
            if memo[float(c)][2] >= memo[float(d)][2]:
                b, d = d, c
                c = b - _INV_PHI * (b - a)
                evaluate([c])
            else:
                a, c = c, d
                d = a + _INV_PHI * (b - a)
                evaluate([d])

    best = max(memo, key=lambda d: memo[d][2])
    # polish: re-measure the winner with a longer series; its burned-in
    # rows come straight from the service state cache
    n_polish = int(polish_steps if polish_steps is not None
                   else 2 * spec.n_steps)
    resp = service.submit(probe_spec(best, n_polish), requester="refiner")
    rec = {r.request_id: r for r in service.drain()}[resp.request_id]
    rec = rec.result.records[0]
    eff_star = float(efficiency(rec.u, rec.w))
    evaluations.append((float(best), eff_star))
    return RefinedWindow(
        L=L, n_v=n_v, delta_star=float(best), eff_star=eff_star,
        u_star=float(rec.u), w_star=float(rec.w), bracket=bracket,
        evaluations=tuple(evaluations), rounds=done, interior=interior)

"""Optimal window width Δ*: the paper's tuning-parameter claim, quantified.

The paper's closing argument (Sec. V): the window width Δ is a *tuning
parameter* — "for a given volume load per processor, [it] could be adjusted
to optimize the utilization so as to maximize the efficiency".  The two
sides of the trade-off, both measured by a window sweep:

* utilization u(Δ) rises monotonically with Δ (more PEs clear the window
  rule per step) and saturates at the unconstrained value;
* the horizon width w(Δ) also rises with Δ — and the width *is* the cost of
  the measurement phase: every PE must hold its state history across the
  horizon extent for state saving / data collection, so memory and
  measurement latency grow with w (that is the phase that fails to scale
  without the window).

We therefore score a window by utilization per unit width-bounded cost::

    efficiency(Δ) = u(Δ) / (1 + w(Δ))

(the 1 is the O(1) per-event compute+communication cost floor; ``w`` is the
steady-state width ⟨sqrt(w²)⟩).  Small Δ throttles u, large Δ pays
unbounded width — the maximizer Δ* is interior, which is exactly the
paper's qualitative claim and what tests/test_experiments.py asserts.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .sweep import SweepResult, WindowSweep, run_window_sweep


def efficiency(u, w):
    """Utilization per unit width-bounded cost, u / (1 + w) (elementwise)."""
    return np.asarray(u, dtype=float) / (1.0 + np.asarray(w, dtype=float))


@dataclasses.dataclass(frozen=True)
class OptimalWindow:
    """The efficiency curve of one (L, N_V) grid point and its maximizer."""

    L: int
    n_v: int
    deltas: tuple[float, ...]      # sorted, as swept (inf allowed, last)
    eff: tuple[float, ...]         # efficiency per Δ, same order
    u: tuple[float, ...]
    w: tuple[float, ...]
    delta_star: float              # grid maximizer of the efficiency
    eff_star: float
    interior: bool                 # Δ* strictly inside the swept grid

    def as_dict(self) -> dict:
        """JSON-ready dict (``inf`` spelled as the string ``"inf"``)."""
        d = dataclasses.asdict(self)
        d["deltas"] = ["inf" if math.isinf(x) else x for x in self.deltas]
        for k in ("deltas", "eff", "u", "w"):
            d[k] = list(d[k])
        return d


def find_optimal_window(result: SweepResult, *, L: int,
                        n_v: int) -> OptimalWindow:
    """Locate Δ* on the swept grid of one (L, N_V) point.

    Sorts the records by Δ (inf last), computes the efficiency curve, and
    returns the grid argmax.  ``interior`` reports whether the maximum sits
    strictly between the smallest and largest swept Δ — the paper's
    qualitative prediction for any grid wide enough to bracket the
    trade-off.
    """
    recs = sorted(result.select(L=L, n_v=n_v), key=lambda r: r.delta)
    if not recs:
        raise ValueError(f"no records for L={L}, n_v={n_v}")
    deltas = tuple(r.delta for r in recs)
    u = tuple(r.u for r in recs)
    w = tuple(r.w for r in recs)
    eff = efficiency(u, w)
    i = int(np.argmax(eff))
    return OptimalWindow(
        L=L, n_v=n_v, deltas=deltas, eff=tuple(float(e) for e in eff),
        u=u, w=w, delta_star=deltas[i], eff_star=float(eff[i]),
        interior=0 < i < len(deltas) - 1)


def optimal_windows(spec_or_result: WindowSweep | SweepResult
                    ) -> list[OptimalWindow]:
    """Δ* for every (L, N_V) grid point of a sweep (running it if needed)."""
    result = (spec_or_result if isinstance(spec_or_result, SweepResult)
              else run_window_sweep(spec_or_result))
    return [find_optimal_window(result, L=int(L), n_v=int(n_v))
            for L in result.spec.Ls for n_v in result.spec.n_vs]

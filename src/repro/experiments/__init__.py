"""Experiment layer: the paper's systematic studies, run in batched form.

``sweep`` executes a ``WindowSweep`` spec — grids over (L, N_V volume load,
window Δ including Δ=inf, backend, replicas) — by laying the Δ axis on the
engine's ensemble batch (``PDESEngine.init_sweep``), so one device pass
covers ``replicas x n_windows`` trajectories per (L, N_V) grid point.
``optimal_window`` finds the Δ* that maximizes efficiency (utilization per
unit width-bounded cost), the paper's tuning-parameter claim.
"""
from .optimal_window import (  # noqa: F401
    OptimalWindow,
    RefinedWindow,
    efficiency,
    find_optimal_window,
    optimal_windows,
    refine_optimal_window,
)
from .sweep import (  # noqa: F401
    MeshSweepPlan,
    SweepRecord,
    SweepResult,
    WindowSweep,
    plan_mesh_sweep,
    run_window_sweep,
    serial_window_sweep,
)

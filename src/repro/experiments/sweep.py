"""Batched window-sweep experiments: the paper's systematic study as one spec.

The paper's core results are sweeps: vary the ring size L, the volume load
per processor N_V, and the moving-window width Δ, then measure steady-state
utilization, horizon width, and progress rate (Kolakowska & Novotny,
cs/0211013; update statistics follow-up cond-mat/0306222).  A
``WindowSweep`` describes the full grid; ``run_window_sweep`` executes it.

Execution model: ring shapes differ across (L, N_V), so those axes are
separate compiles — but the whole Δ axis of one grid point runs in a
*single* device pass.  ``PDESEngine.init_sweep`` lays the Δ grid on the
ensemble axis (``B = n_windows * replicas`` rows, a per-row Δ operand all
the way down into the fused kernel), which is the flattened form of
vmapping the window state over Δ on top of the replica batch.  The serial
per-Δ loop (``serial_window_sweep``) is kept as the bit-identical oracle —
window ``w`` of the batched run consumes the counter-stream slice
``trial_base = w * replicas``, so the two agree exactly, not statistically
(tests/test_experiments.py); it is also the baseline the ``window_sweep``
benchmark beats.

**Multi-device sweeps**: pass ``mesh=`` (and optionally ``dist=``) with
``backend="sharded"`` and the same Δ-on-the-ensemble-axis layout shards
over the mesh — the per-row Δ column gets the identical ensemble-axis
sharding as the tau rows, so every shard sees its own rows' window widths.
``plan_mesh_sweep`` is the grid scheduler: it checks the ring divides the
mesh ring axis, pads ragged Δ-batches up to a multiple of the ensemble
extent (pad rows run unconstrained, ``Δ = inf``, and are sliced off before
``measurement.sweep_reduce`` ever sees them), and rounds the burn-in up to
a whole number of ``k_fuse`` chunks (the sharded runtime advances whole
chunks only).  Because every row's counter stream depends only on its own
global trial index, the sharded pass is *bit-identical* to the
single-device serial loop — asserted on a multi-device CPU mesh in
tests/test_sharded_sweep.py.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
from typing import Sequence

import numpy as np

from ..core import measurement
from ..core.engine import PDESEngine
from ..core.ensemble import default_burn_in
from ..core.horizon import PDESConfig
from ..obs.trace import span as _span


def _sync_if_traced(sp, tree) -> None:
    """Block on async-dispatched device work, but only inside a live span.

    Tracing wants honest phase attribution (the burn span should contain
    the burn's device time, not leak it into whoever touches the arrays
    next); untraced runs keep JAX's async dispatch exactly as before, so
    telemetry-off timing and values are untouched.  Values are never
    affected either way — blocking only awaits completion.
    """
    if sp is not None:
        import jax
        jax.block_until_ready(tree)


@dataclasses.dataclass(frozen=True)
class WindowSweep:
    """One batched window-sweep study (the paper's full grid as a spec).

    Attributes:
      Ls: ring sizes (number of PEs).
      n_vs: volume loads per PE (N_V in the paper).
      deltas: moving-window widths; ``math.inf`` = unconstrained scheme.
      replicas: independent trajectories per (L, N_V, Δ) point.
      n_steps: recorded measurement steps per grid point.
      burn_in: steps discarded before measurement; None = heuristic
        (``ensemble.default_burn_in`` of the widest window in the sweep).
      backend: any single-device ``PDESEngine`` backend.
      window: "exact" | "stale" GVT window mode.
      k_fuse: engine chunk depth.
      rd_mode: random-deposition limit (drop the causality rule).
      border_both: Eq. (1) literal both-neighbor check (PDESConfig).
      steady_frac: trailing fraction of the recorded series treated as
        steady state when reducing (``measurement.sweep_reduce``).
      seed: counter-stream seed; grid points are decorrelated by their
        trial-index blocks, not by reseeding.
    """

    Ls: Sequence[int] = (64,)
    n_vs: Sequence[int] = (1,)
    deltas: Sequence[float] = (math.inf,)
    replicas: int = 16
    n_steps: int = 400
    burn_in: int | None = None
    backend: str = "reference"
    window: str = "exact"
    k_fuse: int = 16
    rd_mode: bool = False
    border_both: bool = False
    steady_frac: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if not self.Ls or not self.n_vs or not self.deltas:
            raise ValueError("Ls, n_vs and deltas must all be non-empty")
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        if len(set(self.deltas)) != len(self.deltas):
            raise ValueError(f"duplicate window widths: {self.deltas}")

    @property
    def n_windows(self) -> int:
        """Number of Δ values in the grid (ensemble rows per replica)."""
        return len(self.deltas)

    @property
    def n_trajectories(self) -> int:
        """Trajectories advanced per (L, N_V) grid point in one device pass."""
        return self.n_windows * self.replicas

    def burn_in_for(self, cfg: PDESConfig) -> int:
        """Shared burn-in of one grid point: the widest window dominates."""
        if self.burn_in is not None:
            return self.burn_in
        return max(
            default_burn_in(dataclasses.replace(cfg, delta=d))
            for d in self.deltas)


def _round_up(n: int, k: int) -> int:
    return -(-n // k) * k


def spec_to_dict(spec: WindowSweep) -> dict:
    """JSON-ready dict of a spec (``inf`` spelled as the string ``"inf"``).

    The canonical on-disk/wire encoding shared by :meth:`SweepResult.to_json`
    and the ``repro.service`` wire schema; inverted by :func:`spec_from_dict`.
    """
    d = dataclasses.asdict(spec)
    d["Ls"] = [int(x) for x in spec.Ls]
    d["n_vs"] = [int(x) for x in spec.n_vs]
    d["deltas"] = ["inf" if math.isinf(x) else float(x) for x in spec.deltas]
    return d


def spec_from_dict(d: dict) -> WindowSweep:
    """Rebuild a :class:`WindowSweep` from :func:`spec_to_dict` output."""
    d = dict(d)
    d["Ls"] = tuple(int(x) for x in d["Ls"])
    d["n_vs"] = tuple(int(x) for x in d["n_vs"])
    d["deltas"] = tuple(math.inf if x == "inf" else float(x)
                        for x in d["deltas"])
    return WindowSweep(**d)


def _derive_dist(spec: WindowSweep):
    """The DistConfig ``PDESEngine`` would derive for this spec (same rule)."""
    from ..core.distributed import DistConfig
    return DistConfig(mode="exact" if spec.window == "exact" else "commavoid",
                      k_chunk=spec.k_fuse)


@dataclasses.dataclass(frozen=True)
class MeshSweepPlan:
    """How one (L, N_V) grid point of a sweep maps onto the device mesh.

    Attributes:
      L, n_v: the grid point.
      trial_base: counter-stream index of row 0 — identical to the
        single-device pass, so padding never shifts real rows' streams.
      n_rows: real (Δ, replica) rows = ``spec.n_trajectories``.
      n_pad: rows appended so ``n_rows + n_pad`` divides the ensemble
        extent.  Pad rows run unconstrained (``Δ = inf``) on stream indices
        past the real block and are sliced off before reduction.
      ens_extent: product of the mesh ensemble axis sizes.
      ring_extent: mesh ring axis size (must divide L).
      burn_in: the grid point's burn-in, rounded *up* to whole chunks
        (the sharded runtime advances whole ``k_chunk``-step chunks; the
        rounding is the identity when the spec's burn-in already is one,
        which is what the parity tests pass).
    """

    L: int
    n_v: int
    trial_base: int
    n_rows: int
    n_pad: int
    ens_extent: int
    ring_extent: int
    burn_in: int

    @property
    def n_padded(self) -> int:
        """Rows actually laid out on the mesh (``n_rows + n_pad``)."""
        return self.n_rows + self.n_pad


def plan_mesh_sweep(spec: WindowSweep, mesh, dist=None) -> tuple[MeshSweepPlan, ...]:
    """Grid scheduler: pack the sweep's (L, N_V, Δ) points onto a mesh.

    Validates the layout (ring axis divides every L, mesh has the
    ``DistConfig`` axes, whole-chunk step counts) and returns one
    :class:`MeshSweepPlan` per (L, N_V) grid point, in execution order.
    Works on an ``AbstractMesh`` too — planning needs axis sizes only.
    """
    if dist is None:
        dist = _derive_dist(spec)
    missing = [a for a in (*dist.ens_axes, dist.ring_axis)
               if a not in mesh.shape]
    if missing:
        raise ValueError(
            f"mesh axes {tuple(mesh.shape)} lack the DistConfig axes "
            f"{missing}")
    ens = 1
    for a in dist.ens_axes:
        ens *= mesh.shape[a]
    ring = mesh.shape[dist.ring_axis]
    if spec.n_steps % dist.k_chunk:
        raise ValueError(
            f"sharded sweeps advance whole chunks: n_steps={spec.n_steps} "
            f"must be a multiple of k_chunk={dist.k_chunk}")
    plans = []
    base = 0
    for L in spec.Ls:
        if int(L) % ring:
            raise ValueError(
                f"ring axis {dist.ring_axis!r} of extent {ring} does not "
                f"divide L={L}")
        for n_v in spec.n_vs:
            cfg = PDESConfig(L=int(L), n_v=int(n_v), delta=math.inf,
                             rd_mode=spec.rd_mode,
                             border_both=spec.border_both)
            B = spec.n_trajectories
            plans.append(MeshSweepPlan(
                L=int(L), n_v=int(n_v), trial_base=base, n_rows=B,
                n_pad=_round_up(B, ens) - B, ens_extent=ens,
                ring_extent=ring,
                burn_in=_round_up(spec.burn_in_for(cfg), dist.k_chunk)))
            base += B
    return tuple(plans)


@dataclasses.dataclass(frozen=True)
class SweepRecord:
    """Per-(L, N_V, Δ) steady-state estimates (ensemble mean ± std. error)."""

    L: int
    n_v: int
    delta: float
    u: float
    u_err: float
    w2: float
    w2_err: float
    w: float
    wa: float
    spread: float
    rate: float
    rate_err: float

    def as_dict(self) -> dict:
        """JSON-ready dict of the record's scalar fields."""
        d = dataclasses.asdict(self)
        # JSON has no inf literal; the canonical on-disk spelling is "inf".
        if math.isinf(self.delta):
            d["delta"] = "inf"
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "SweepRecord":
        """Inverse of :meth:`as_dict` (decodes the ``"inf"`` spelling)."""
        d = dict(d)
        d["delta"] = math.inf if d["delta"] == "inf" else float(d["delta"])
        return cls(**d)


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """All records of one executed sweep, plus selection helpers."""

    spec: WindowSweep
    records: tuple[SweepRecord, ...]

    def select(self, *, L: int | None = None, n_v: int | None = None,
               delta: float | None = None) -> list[SweepRecord]:
        """Records matching every given coordinate (None = don't filter)."""
        out = []
        for r in self.records:
            if L is not None and r.L != L:
                continue
            if n_v is not None and r.n_v != n_v:
                continue
            if delta is not None and r.delta != delta:
                continue
            out.append(r)
        return out

    def as_dict(self) -> dict:
        """JSON-ready ``{"spec": ..., "records": [...]}`` encoding."""
        return {"spec": spec_to_dict(self.spec),
                "records": [r.as_dict() for r in self.records]}

    @classmethod
    def from_dict(cls, d: dict) -> "SweepResult":
        """Inverse of :meth:`as_dict` — the wire-layer decode path."""
        return cls(spec=spec_from_dict(d["spec"]),
                   records=tuple(SweepRecord.from_dict(r)
                                 for r in d["records"]))

    def to_json(self, path: str | pathlib.Path) -> pathlib.Path:
        """Write spec + records to ``path`` as JSON; returns the path."""
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.as_dict(), indent=1))
        return path


def _grid_point_records(spec: WindowSweep, cfg: PDESConfig,
                        red: dict) -> list[SweepRecord]:
    out = []
    for w, d in enumerate(spec.deltas):
        out.append(SweepRecord(
            L=cfg.L, n_v=cfg.n_v, delta=float(d),
            u=float(red["u"][w]), u_err=float(red["u_err"][w]),
            w2=float(red["w2"][w]), w2_err=float(red["w2_err"][w]),
            w=float(red["w"][w]), wa=float(red["wa"][w]),
            spread=float(red["spread"][w]),
            rate=float(red["rate"][w]), rate_err=float(red["rate_err"][w])))
    return out


def _engine(spec: WindowSweep, cfg: PDESConfig, mesh=None,
            dist=None) -> PDESEngine:
    return PDESEngine(cfg, backend=spec.backend, window=spec.window,
                      k_fuse=spec.k_fuse, mesh=mesh, dist=dist)


def _check_mesh_args(spec: WindowSweep, mesh) -> None:
    if spec.backend == "sharded" and mesh is None:
        raise ValueError(
            "backend='sharded' sweeps need a device mesh: pass mesh= "
            "(and optionally dist=)")
    if mesh is not None and spec.backend != "sharded":
        raise ValueError(
            f"mesh= is only meaningful for backend='sharded', "
            f"got backend={spec.backend!r}")


def run_window_sweep(spec: WindowSweep, *, mesh=None, dist=None) -> SweepResult:
    """Execute a sweep: one batched device pass per (L, N_V) grid point.

    Every Δ (and every replica) of a grid point advances in the same engine
    call — ``spec.n_trajectories`` rows per pass — then
    ``measurement.sweep_reduce`` collapses the batch to per-Δ steady-state
    estimates.  With ``backend="sharded"`` pass ``mesh=`` (and optionally
    ``dist=``): the pass shards over the mesh per :func:`plan_mesh_sweep`,
    with ragged Δ-batches padded to the ensemble extent and un-padded
    before reduction.
    """
    _check_mesh_args(spec, mesh)
    if mesh is not None:
        return _run_window_sweep_sharded(spec, mesh, dist)
    records = []
    grid_base = 0
    for L in spec.Ls:
        for n_v in spec.n_vs:
            cfg = PDESConfig(L=int(L), n_v=int(n_v), delta=math.inf,
                             rd_mode=spec.rd_mode,
                             border_both=spec.border_both)
            eng = _engine(spec, cfg)
            state, drows = eng.init_sweep(spec.deltas, spec.replicas)
            burn = spec.burn_in_for(cfg)
            point = {"L": cfg.L, "n_v": cfg.n_v,
                     "rows": spec.n_trajectories}
            if burn:
                with _span("burn", args=dict(point, steps=burn)) as sp:
                    state = eng.burn_in(state, spec.seed, burn,
                                        deltas=drows, trial_base=grid_base)
                    _sync_if_traced(sp, state)
            with _span("measure", args=dict(point,
                                            steps=spec.n_steps)) as sp:
                _, stats = eng.run(state, spec.seed, spec.n_steps,
                                   deltas=drows, trial_base=grid_base)
                _sync_if_traced(sp, stats)
            with _span("reduce", args=point):
                red = measurement.sweep_reduce(
                    stats, spec.n_windows, spec.replicas,
                    steady_frac=spec.steady_frac)
            records.extend(_grid_point_records(spec, cfg, red))
            grid_base += spec.n_trajectories
    return SweepResult(spec=spec, records=tuple(records))


def _run_window_sweep_sharded(spec: WindowSweep, mesh, dist) -> SweepResult:
    """Mesh execution of :func:`run_window_sweep` (same records contract).

    Pad rows (ragged Δ-batch -> ensemble-extent multiple) run with
    ``Δ = inf`` on counter-stream indices past the grid point's real block;
    they are sliced off the recorded stats *before*
    ``measurement.sweep_reduce``, so the steady-state estimates are
    computed from exactly the rows the single-device pass produces.
    """
    import jax
    import jax.numpy as jnp
    records = []
    for plan in plan_mesh_sweep(spec, mesh, dist):
        cfg = PDESConfig(L=plan.L, n_v=plan.n_v, delta=math.inf,
                         rd_mode=spec.rd_mode, border_both=spec.border_both)
        eng = _engine(spec, cfg, mesh=mesh, dist=dist)
        state, drows = eng.init_sweep(spec.deltas, spec.replicas)
        if plan.n_pad:
            state = eng.init(plan.n_padded)
            drows = jnp.concatenate(
                [drows, jnp.full((plan.n_pad,), jnp.inf, drows.dtype)])
        point = {"L": plan.L, "n_v": plan.n_v, "rows": plan.n_rows,
                 "n_pad": plan.n_pad}
        if plan.burn_in:
            with _span("burn", args=dict(point, steps=plan.burn_in)) as sp:
                state = eng.burn_in(state, spec.seed, plan.burn_in,
                                    deltas=drows,
                                    trial_base=plan.trial_base)
                _sync_if_traced(sp, state)
        with _span("measure", args=dict(point, steps=spec.n_steps)) as sp:
            _, stats = eng.run(state, spec.seed, spec.n_steps, deltas=drows,
                               trial_base=plan.trial_base)
            _sync_if_traced(sp, stats)
        with _span("reduce", args=point):
            if plan.n_pad:
                stats = jax.tree.map(lambda a: a[:, :plan.n_rows], stats)
            red = measurement.sweep_reduce(
                stats, spec.n_windows, spec.replicas,
                steady_frac=spec.steady_frac)
        records.extend(_grid_point_records(spec, cfg, red))
    return SweepResult(spec=spec, records=tuple(records))


def serial_window_sweep(spec: WindowSweep, *, mesh=None,
                        dist=None) -> SweepResult:
    """The same study as a serial per-Δ engine loop (oracle + baseline).

    Window ``w`` runs with a static ``cfg.delta`` and
    ``trial_base = w * replicas``, i.e. on exactly the counter-stream rows
    the batched pass assigns it — trajectories are bit-identical to
    ``run_window_sweep``, at one engine call per Δ instead of one per grid
    point.  ``mesh=``/``dist=`` run each per-Δ call on the sharded backend
    (``replicas`` must then divide the mesh ensemble extent) — the serial
    baseline the ``window_sweep_sharded`` benchmark measures against.
    """
    _check_mesh_args(spec, mesh)
    burn_quantum = 1
    if mesh is not None:
        dcfg = dist if dist is not None else _derive_dist(spec)
        ens = 1
        for a in dcfg.ens_axes:
            ens *= mesh.shape[a]
        if spec.replicas % ens:
            raise ValueError(
                f"serial sharded sweeps run replicas={spec.replicas} rows "
                f"per engine call; must be a multiple of the ensemble "
                f"extent {ens}")
        # match the batched mesh pass's whole-chunk burn-in rounding
        burn_quantum = dcfg.k_chunk
    records = []
    grid_base = 0
    for L in spec.Ls:
        for n_v in spec.n_vs:
            per_delta_stats = []
            burn = None
            for w, d in enumerate(spec.deltas):
                cfg = PDESConfig(L=int(L), n_v=int(n_v), delta=float(d),
                                 rd_mode=spec.rd_mode,
                                 border_both=spec.border_both)
                if burn is None:
                    burn = _round_up(spec.burn_in_for(cfg), burn_quantum)
                eng = _engine(spec, cfg, mesh=mesh, dist=dist)
                state = eng.init(spec.replicas)
                base = grid_base + w * spec.replicas
                if burn:
                    state = eng.burn_in(state, spec.seed, burn,
                                        trial_base=base)
                _, stats = eng.run(state, spec.seed, spec.n_steps,
                                   trial_base=base)
                per_delta_stats.append(stats)
            joined = type(per_delta_stats[0])(*(
                np.concatenate([np.asarray(getattr(s, f)) for s in
                                per_delta_stats], axis=1)
                for f in per_delta_stats[0]._fields))
            red = measurement.sweep_reduce(
                joined, spec.n_windows, spec.replicas,
                steady_frac=spec.steady_frac)
            cfg0 = PDESConfig(L=int(L), n_v=int(n_v), delta=math.inf,
                              rd_mode=spec.rd_mode,
                              border_both=spec.border_both)
            records.extend(_grid_point_records(spec, cfg0, red))
            grid_base += spec.n_trajectories
    return SweepResult(spec=spec, records=tuple(records))

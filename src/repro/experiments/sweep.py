"""Batched window-sweep experiments: the paper's systematic study as one spec.

The paper's core results are sweeps: vary the ring size L, the volume load
per processor N_V, and the moving-window width Δ, then measure steady-state
utilization, horizon width, and progress rate (Kolakowska & Novotny,
cs/0211013; update statistics follow-up cond-mat/0306222).  A
``WindowSweep`` describes the full grid; ``run_window_sweep`` executes it.

Execution model: ring shapes differ across (L, N_V), so those axes are
separate compiles — but the whole Δ axis of one grid point runs in a
*single* device pass.  ``PDESEngine.init_sweep`` lays the Δ grid on the
ensemble axis (``B = n_windows * replicas`` rows, a per-row Δ operand all
the way down into the fused kernel), which is the flattened form of
vmapping the window state over Δ on top of the replica batch.  The serial
per-Δ loop (``serial_window_sweep``) is kept as the bit-identical oracle —
window ``w`` of the batched run consumes the counter-stream slice
``trial_base = w * replicas``, so the two agree exactly, not statistically
(tests/test_experiments.py); it is also the baseline the ``window_sweep``
benchmark beats.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib
from typing import Sequence

import numpy as np

from ..core import measurement
from ..core.engine import PDESEngine
from ..core.ensemble import default_burn_in
from ..core.horizon import PDESConfig


@dataclasses.dataclass(frozen=True)
class WindowSweep:
    """One batched window-sweep study (the paper's full grid as a spec).

    Attributes:
      Ls: ring sizes (number of PEs).
      n_vs: volume loads per PE (N_V in the paper).
      deltas: moving-window widths; ``math.inf`` = unconstrained scheme.
      replicas: independent trajectories per (L, N_V, Δ) point.
      n_steps: recorded measurement steps per grid point.
      burn_in: steps discarded before measurement; None = heuristic
        (``ensemble.default_burn_in`` of the widest window in the sweep).
      backend: any single-device ``PDESEngine`` backend.
      window: "exact" | "stale" GVT window mode.
      k_fuse: engine chunk depth.
      rd_mode: random-deposition limit (drop the causality rule).
      border_both: Eq. (1) literal both-neighbor check (PDESConfig).
      steady_frac: trailing fraction of the recorded series treated as
        steady state when reducing (``measurement.sweep_reduce``).
      seed: counter-stream seed; grid points are decorrelated by their
        trial-index blocks, not by reseeding.
    """

    Ls: Sequence[int] = (64,)
    n_vs: Sequence[int] = (1,)
    deltas: Sequence[float] = (math.inf,)
    replicas: int = 16
    n_steps: int = 400
    burn_in: int | None = None
    backend: str = "reference"
    window: str = "exact"
    k_fuse: int = 16
    rd_mode: bool = False
    border_both: bool = False
    steady_frac: float = 0.5
    seed: int = 0

    def __post_init__(self):
        if not self.Ls or not self.n_vs or not self.deltas:
            raise ValueError("Ls, n_vs and deltas must all be non-empty")
        if self.replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {self.replicas}")
        if len(set(self.deltas)) != len(self.deltas):
            raise ValueError(f"duplicate window widths: {self.deltas}")

    @property
    def n_windows(self) -> int:
        return len(self.deltas)

    @property
    def n_trajectories(self) -> int:
        """Trajectories advanced per (L, N_V) grid point in one device pass."""
        return self.n_windows * self.replicas

    def burn_in_for(self, cfg: PDESConfig) -> int:
        """Shared burn-in of one grid point: the widest window dominates."""
        if self.burn_in is not None:
            return self.burn_in
        return max(
            default_burn_in(dataclasses.replace(cfg, delta=d))
            for d in self.deltas)


@dataclasses.dataclass(frozen=True)
class SweepRecord:
    """Per-(L, N_V, Δ) steady-state estimates (ensemble mean ± std. error)."""

    L: int
    n_v: int
    delta: float
    u: float
    u_err: float
    w2: float
    w2_err: float
    w: float
    wa: float
    spread: float
    rate: float
    rate_err: float

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        # JSON has no inf literal; the canonical on-disk spelling is "inf".
        if math.isinf(self.delta):
            d["delta"] = "inf"
        return d


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """All records of one executed sweep, plus selection helpers."""

    spec: WindowSweep
    records: tuple[SweepRecord, ...]

    def select(self, *, L: int | None = None, n_v: int | None = None,
               delta: float | None = None) -> list[SweepRecord]:
        out = []
        for r in self.records:
            if L is not None and r.L != L:
                continue
            if n_v is not None and r.n_v != n_v:
                continue
            if delta is not None and r.delta != delta:
                continue
            out.append(r)
        return out

    def to_json(self, path: str | pathlib.Path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        spec = dataclasses.asdict(self.spec)
        spec["Ls"] = list(spec["Ls"])
        spec["n_vs"] = list(spec["n_vs"])
        spec["deltas"] = ["inf" if math.isinf(d) else d
                         for d in spec["deltas"]]
        path.write_text(json.dumps(
            {"spec": spec, "records": [r.as_dict() for r in self.records]},
            indent=1))
        return path


def _grid_point_records(spec: WindowSweep, cfg: PDESConfig,
                        red: dict) -> list[SweepRecord]:
    out = []
    for w, d in enumerate(spec.deltas):
        out.append(SweepRecord(
            L=cfg.L, n_v=cfg.n_v, delta=float(d),
            u=float(red["u"][w]), u_err=float(red["u_err"][w]),
            w2=float(red["w2"][w]), w2_err=float(red["w2_err"][w]),
            w=float(red["w"][w]), wa=float(red["wa"][w]),
            spread=float(red["spread"][w]),
            rate=float(red["rate"][w]), rate_err=float(red["rate_err"][w])))
    return out


def _engine(spec: WindowSweep, cfg: PDESConfig) -> PDESEngine:
    return PDESEngine(cfg, backend=spec.backend, window=spec.window,
                      k_fuse=spec.k_fuse)


def run_window_sweep(spec: WindowSweep) -> SweepResult:
    """Execute a sweep: one batched device pass per (L, N_V) grid point.

    Every Δ (and every replica) of a grid point advances in the same engine
    call — ``spec.n_trajectories`` rows per pass — then
    ``measurement.sweep_reduce`` collapses the batch to per-Δ steady-state
    estimates.
    """
    records = []
    grid_base = 0
    for L in spec.Ls:
        for n_v in spec.n_vs:
            cfg = PDESConfig(L=int(L), n_v=int(n_v), delta=math.inf,
                             rd_mode=spec.rd_mode,
                             border_both=spec.border_both)
            eng = _engine(spec, cfg)
            state, drows = eng.init_sweep(spec.deltas, spec.replicas)
            burn = spec.burn_in_for(cfg)
            if burn:
                state = eng.burn_in(state, spec.seed, burn, deltas=drows,
                                    trial_base=grid_base)
            _, stats = eng.run(state, spec.seed, spec.n_steps, deltas=drows,
                               trial_base=grid_base)
            red = measurement.sweep_reduce(
                stats, spec.n_windows, spec.replicas,
                steady_frac=spec.steady_frac)
            records.extend(_grid_point_records(spec, cfg, red))
            grid_base += spec.n_trajectories
    return SweepResult(spec=spec, records=tuple(records))


def serial_window_sweep(spec: WindowSweep) -> SweepResult:
    """The same study as a serial per-Δ engine loop (oracle + baseline).

    Window ``w`` runs with a static ``cfg.delta`` and
    ``trial_base = w * replicas``, i.e. on exactly the counter-stream rows
    the batched pass assigns it — trajectories are bit-identical to
    ``run_window_sweep``, at one engine call per Δ instead of one per grid
    point.
    """
    records = []
    grid_base = 0
    for L in spec.Ls:
        for n_v in spec.n_vs:
            per_delta_stats = []
            burn = None
            for w, d in enumerate(spec.deltas):
                cfg = PDESConfig(L=int(L), n_v=int(n_v), delta=float(d),
                                 rd_mode=spec.rd_mode,
                                 border_both=spec.border_both)
                if burn is None:
                    burn = spec.burn_in_for(cfg)
                eng = _engine(spec, cfg)
                state = eng.init(spec.replicas)
                base = grid_base + w * spec.replicas
                if burn:
                    state = eng.burn_in(state, spec.seed, burn,
                                        trial_base=base)
                _, stats = eng.run(state, spec.seed, spec.n_steps,
                                   trial_base=base)
                per_delta_stats.append(stats)
            joined = type(per_delta_stats[0])(*(
                np.concatenate([np.asarray(getattr(s, f)) for s in
                                per_delta_stats], axis=1)
                for f in per_delta_stats[0]._fields))
            red = measurement.sweep_reduce(
                joined, spec.n_windows, spec.replicas,
                steady_frac=spec.steady_frac)
            cfg0 = PDESConfig(L=int(L), n_v=int(n_v), delta=math.inf,
                              rd_mode=spec.rd_mode,
                              border_both=spec.border_both)
            records.extend(_grid_point_records(spec, cfg0, red))
            grid_base += spec.n_trajectories
    return SweepResult(spec=spec, records=tuple(records))

"""Sharded checkpointing with elastic resharding on restore.

Format: one ``.npz`` file holding all leaves (flattened key paths) plus a
JSON manifest (step, config name, tree structure, dtypes).  Restore places
leaves onto ANY mesh via NamedSharding — the mesh shape may differ from the
one that saved (elastic restart after losing/gaining pods), because leaves
are stored unsharded and re-partitioned on load.

Async mode: a background thread serializes and writes while training
continues (the caller passes a host copy; jax arrays are materialized with
np.asarray before the thread starts so device buffers are not held).
"""
from __future__ import annotations

import json
import pathlib
import re
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    return {jax.tree_util.keystr(kp): v for kp, v in leaves}


def _key_for(s: str) -> str:
    return re.sub(r"[^\w\.\-]", "_", s)


def save(state, path, *, step: int | None = None, extra: dict | None = None):
    """Synchronous checkpoint write.  ``state`` is any pytree of arrays."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    flat = _flatten(state)
    arrays = {}
    manifest = {"keys": {}, "step": step, "extra": extra or {}}
    for i, (k, v) in enumerate(sorted(flat.items())):
        nk = f"a{i}"
        arrays[nk] = np.asarray(v)
        manifest["keys"][k] = nk
    tmp = pathlib.Path(str(path) + ".tmp.npz")   # ends in .npz: savez keeps it
    np.savez(tmp, **arrays)
    tmp.rename(str(path) + ".npz")
    pathlib.Path(str(path) + ".json").write_text(json.dumps(manifest))
    return path


class AsyncCheckpointer:
    """Fire-and-forget checkpoint writes on a background thread."""

    def __init__(self):
        self._thread: threading.Thread | None = None

    def save(self, state, path, **kw):
        host_state = jax.tree.map(np.asarray, state)   # snapshot now
        self.wait()
        self._thread = threading.Thread(
            target=save, args=(host_state, path), kwargs=kw, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def restore(path, like, shardings=None):
    """Load a checkpoint into the structure of ``like`` (a pytree template).

    ``shardings``: optional matching pytree of NamedSharding — enables
    elastic restore onto a different mesh than the checkpoint was saved from.
    """
    path = pathlib.Path(path)
    manifest = json.loads(pathlib.Path(str(path) + ".json").read_text())
    data = np.load(str(path) + ".npz")
    flat_like = _flatten(like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for k, template in flat_like.items():
        nk = manifest["keys"].get(k)
        if nk is None:
            raise KeyError(f"checkpoint missing leaf {k}")
        arr = data[nk]
        if tuple(arr.shape) != tuple(template.shape):
            raise ValueError(f"shape mismatch for {k}: "
                             f"{arr.shape} vs {template.shape}")
        arr = arr.astype(template.dtype)
        sh = flat_sh.get(k)
        out[k] = jax.device_put(arr, sh) if sh is not None else jax.device_put(arr)
    # rebuild the tree in `like`'s structure
    leaves_paths = jax.tree_util.tree_leaves_with_path(like)
    treedef = jax.tree_util.tree_structure(like)
    ordered = [out[jax.tree_util.keystr(kp)] for kp, _ in leaves_paths]
    return jax.tree_util.tree_unflatten(treedef, ordered)


def latest_step(ckpt_dir) -> int | None:
    d = pathlib.Path(ckpt_dir)
    if not d.exists():
        return None
    steps = []
    for p in d.glob("step_*.json"):
        try:
            steps.append(int(p.stem.split("_")[1]))
        except (IndexError, ValueError):
            continue
    return max(steps) if steps else None

"""Training substrate: step factory, checkpointing, fault tolerance."""
from .train_step import (init_train_state, make_decode_step,  # noqa: F401
                         make_prefill_step, make_train_step, state_pspecs)
from . import checkpoint, fault  # noqa: F401

"""Fault tolerance: checkpoint/restart, simulated node failure, elastic
re-meshing, and Δ-window straggler absorption.

On a real cluster the failure signal comes from the coordinator (a missing
heartbeat); here ``FaultInjector`` raises ``SimulatedFailure`` at configured
steps so the recovery path is exercised end-to-end in tests: the controller
restores the last consistent checkpoint (whose frontier is the Δ-scheduler's
GVT) and resumes — optionally on a *different* mesh shape (elastic restart),
which works because checkpoints are stored unsharded and re-partitioned on
load (checkpoint.py).

Straggler mitigation is not a separate mechanism: it *is* the Δ-window rule
(distributed/delta_sync.py).  A straggling worker bounds the cluster's
progress only through the GVT; healthy workers keep running up to Δ ahead,
and the utilization cost of a given straggler distribution is exactly the
paper's u(Δ) curve.
"""
from __future__ import annotations

import dataclasses
import pathlib

import numpy as np

from . import checkpoint
from ..distributed.delta_sync import DeltaScheduler


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FaultInjector:
    """Deterministic failure schedule for tests/examples."""

    fail_at_steps: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class RecoveryConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3


class TrainController:
    """Run loop with checkpoint/restart and Δ-window scheduling.

    ``step_fn(state, batch) -> (state, metrics)`` is the jitted train step;
    ``data_iter(step)`` yields batches; recovery restores the latest
    checkpoint and replays the data stream deterministically (the pipeline
    is counter-based, so batch t is reproducible — data/pipeline.py).
    """

    def __init__(self, step_fn, init_state, data_fn, rc: RecoveryConfig,
                 scheduler: DeltaScheduler | None = None,
                 injector: FaultInjector | None = None,
                 state_shardings=None):
        self.step_fn = step_fn
        self.state = init_state
        self.data_fn = data_fn
        self.rc = rc
        self.scheduler = scheduler
        self.injector = injector
        self.state_shardings = state_shardings
        self.ckpt = checkpoint.AsyncCheckpointer()
        self.step = 0
        self.restarts = 0

    def _ckpt_path(self, step):
        return pathlib.Path(self.rc.ckpt_dir) / f"step_{step}"

    def save_now(self):
        self.ckpt.save(self.state, self._ckpt_path(self.step), step=self.step)
        self.ckpt.wait()

    def restore_latest(self):
        last = checkpoint.latest_step(self.rc.ckpt_dir)
        if last is None:
            self.step = 0
            return False
        self.state = checkpoint.restore(
            self._ckpt_path(last), self.state, self.state_shardings)
        self.step = last
        return True

    def run(self, n_steps: int, max_restarts: int = 10):
        metrics_log = []
        while self.step < n_steps:
            try:
                if self.injector is not None:
                    self.injector.check(self.step)
                batch = self.data_fn(self.step)
                self.state, metrics = self.step_fn(self.state, batch)
                self.step += 1
                if self.scheduler is not None:
                    self.scheduler.offer()
                metrics_log.append(
                    {k: float(np.asarray(v)) for k, v in metrics.items()})
                if self.step % self.rc.ckpt_every == 0:
                    self.ckpt.save(self.state, self._ckpt_path(self.step),
                                   step=self.step)
            except SimulatedFailure:
                self.restarts += 1
                if self.restarts > max_restarts:
                    raise
                self.ckpt.wait()
                self.restore_latest()
        self.ckpt.wait()
        return metrics_log

"""Train/serve step factories with sharding annotations and microbatching."""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..distributed.sharding import Parallelism, make_constrain, param_pspecs
from ..models import build_model
from ..optim import adamw
from ..optim.adamw import AdamWConfig


def make_train_step(cfg: ModelConfig, par: Parallelism | None = None,
                    opt_cfg: AdamWConfig = AdamWConfig()):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params", "opt": {m, v, count}, "step"}.  Gradient accumulation
    over cfg.microbatches splits the batch's leading dim.
    """
    constrain = make_constrain(par, cfg.n_heads) if par is not None \
        else (lambda x, k: x)
    model = build_model(cfg, constrain)
    n_micro = cfg.microbatches

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                acc, = carry
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / n_micro, acc, g)
                return (acc,), (l, m)

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda a: a.reshape((n_micro, a.shape[0] // n_micro)
                                    + a.shape[1:]), batch)
            (grads,), (losses, metricses) = jax.lax.scan(micro, (acc0,), mbs)
            loss = jnp.mean(losses)
            metrics = jax.tree.map(jnp.mean, metricses)
        new_params, new_opt, opt_metrics = adamw.update(
            grads, state["opt"], params, opt_cfg)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return {"params": new_params, "opt": new_opt,
                "step": state["step"] + 1}, metrics

    return model, train_step


def init_train_state(model, key):
    params = model.init(key)
    return {"params": params, "opt": adamw.init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_pspecs(state, par: Parallelism):
    pp = param_pspecs(state["params"], par)
    return {
        "params": pp,
        "opt": {"m": pp, "v": pp, "count": P()},
        "step": P(),
    }


def make_prefill_step(cfg: ModelConfig, par: Parallelism | None = None):
    constrain = make_constrain(par, cfg.n_heads) if par is not None \
        else (lambda x, k: x)
    model = build_model(cfg, constrain)

    def prefill(params, batch):
        return model.prefill(params, batch)

    return model, prefill


def make_decode_step(cfg: ModelConfig, par: Parallelism | None = None):
    constrain = make_constrain(par, cfg.n_heads) if par is not None \
        else (lambda x, k: x)
    model = build_model(cfg, constrain)

    def decode(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    return model, decode

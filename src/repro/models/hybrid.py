"""Hybrid SSM + shared-attention model (zamba2 family).

Backbone: a stack of Mamba2 blocks.  After every ``hybrid_period`` SSM layers
a *shared* transformer block (one weight set reused at every application, as
in Zamba/Zamba2) runs on ``proj([hidden ; original_embedding])`` — the concat
re-injects the token embedding at depth, per the Zamba design; the block's
delta (its attention+FFN contribution) is added back to the residual stream.
We simplify the released model's per-application LoRA deltas away (noted in
DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from .layers import dense_init, embed_init, embed_lookup
from .ssm import ssm_apply, ssm_decode_step, ssm_init
from .transformer import (Constrain, _dt, _noop, _norm, _norm_init, _remat,
                          attn_prefill_kv, chunked_ce, layer_apply,
                          layer_decode, layer_init)
from typing import TYPE_CHECKING
if TYPE_CHECKING:  # avoid circular import; hints only
    from ..configs.base import ModelConfig


@dataclasses.dataclass
class HybridModel:
    cfg: ModelConfig
    constrain: Constrain = _noop

    @property
    def n_shared(self) -> int:
        return self.cfg.n_layers // self.cfg.hybrid_period

    def init(self, key):
        cfg = self.cfg
        pd = _dt(cfg.param_dtype)
        k_emb, k_ssm, k_shared, k_proj = jax.random.split(key, 4)
        ssm_keys = jax.random.split(k_ssm, cfg.n_layers).reshape(
            self.n_shared, cfg.hybrid_period)

        def one_ssm(k):
            return {"norm": _norm_init(cfg, pd),
                    "ssm": ssm_init(k, cfg.ssm, pd)}

        return {
            "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, pd),
            "ssm_layers": jax.vmap(jax.vmap(one_ssm))(ssm_keys),
            "shared": layer_init(k_shared, cfg, pd),          # one weight set
            "shared_in": dense_init(k_proj, 2 * cfg.d_model, (cfg.d_model,), pd),
            "final_norm": _norm_init(cfg, pd),
        }

    def _cast(self, params, cd):
        return jax.tree.map(
            lambda a: a.astype(cd) if a.dtype == jnp.float32 and a.ndim > 1
            else a, params)

    def _shared_delta(self, params, x, emb0, positions, cd):
        """Shared block contribution on proj([x ; emb0])."""
        cfg = self.cfg
        xin = jnp.concatenate([x, emb0], axis=-1) @ params["shared_in"].astype(cd)
        out, _ = layer_apply(xin, params["shared"], cfg, kind="full",
                             constrain=self.constrain, positions=positions)
        return out - xin

    # ---- train ----
    def loss(self, params, batch):
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        params = self._cast(params, cd)
        x = embed_lookup(params["embed"], batch["tokens"], cd)
        x = self.constrain(x, "act")
        emb0 = x
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]

        def group_body(x, gparams):
            for j in range(cfg.hybrid_period):
                pj = jax.tree.map(lambda a: a[j], gparams)
                h, _ = ssm_apply(_norm(x, pj["norm"], cfg), pj["ssm"], cfg.ssm, cd)
                x = self.constrain(x + h, "act")
            x = x + self._shared_delta(params, x, emb0, positions, cd)
            return self.constrain(x, "act"), None

        body = _remat(group_body, cfg.remat)
        x, _ = lax.scan(lambda c, xs: body(c, xs), x, params["ssm_layers"])
        x = _norm(x, params["final_norm"], cfg)
        nll, n = chunked_ce(x, params["embed"]["table"], batch["labels"], cfg,
                            self.constrain)
        loss = nll / jnp.maximum(n, 1)
        return loss, {"nll": loss}

    # ---- serve ----
    def init_cache(self, batch_size: int, max_len: int):
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        s = cfg.ssm
        G, R, B = self.n_shared, cfg.hybrid_period, batch_size
        return {
            "ssm": {
                "ssm": jnp.zeros((G, R, B, s.n_heads, s.head_dim, s.d_state),
                                 jnp.float32),
                "conv": jnp.zeros((G, R, B, s.d_conv - 1, s.conv_dim), cd),
            },
            "k": jnp.zeros((G, B, max_len, cfg.n_kv_heads, cfg.head_dim), cd),
            "v": jnp.zeros((G, B, max_len, cfg.n_kv_heads, cfg.head_dim), cd),
        }

    def prefill(self, params, batch):
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        params = self._cast(params, cd)
        x = embed_lookup(params["embed"], batch["tokens"], cd)
        emb0 = x
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]

        def group_body(x, gparams):
            new_ssm = []
            for j in range(cfg.hybrid_period):
                pj = jax.tree.map(lambda a: a[j], gparams)
                h, c = ssm_apply(_norm(x, pj["norm"], cfg), pj["ssm"], cfg.ssm, cd)
                new_ssm.append(c)
                x = x + h
            xin = jnp.concatenate([x, emb0], axis=-1) \
                @ params["shared_in"].astype(cd)
            xn = _norm(xin, params["shared"]["ln1"], cfg)
            k, v = attn_prefill_kv(xn, params["shared"]["attn"], cfg, cd,
                                   self.constrain, positions)
            out, _ = layer_apply(xin, params["shared"], cfg, kind="full",
                                 constrain=self.constrain, positions=positions)
            x = x + (out - xin)
            stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_ssm)
            return x, (stacked, k, v)

        x, (ssm_caches, ks, vs) = lax.scan(
            lambda c, xs: group_body(c, xs), x, params["ssm_layers"])
        x = _norm(x, params["final_norm"], cfg)
        logits = jnp.einsum(
            "bd,vd->bv", x[:, -1], params["embed"]["table"].astype(cd),
            preferred_element_type=jnp.float32)[:, :cfg.vocab_size]
        cache = {"ssm": ssm_caches, "k": ks, "v": vs}
        return logits, cache

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        params = self._cast(params, cd)
        x = embed_lookup(params["embed"], tokens, cd)       # (B,1,d)
        emb0 = x

        def group_body(x, inputs):
            gparams, gcache = inputs
            new_ssm = []
            for j in range(cfg.hybrid_period):
                pj = jax.tree.map(lambda a: a[j], gparams)
                cj = jax.tree.map(lambda a: a[j], gcache["ssm"])
                h, cj2 = ssm_decode_step(
                    _norm(x, pj["norm"], cfg)[:, 0], cj, pj["ssm"], cfg.ssm, cd)
                new_ssm.append(cj2)
                x = x + h[:, None, :]
            xin = jnp.concatenate([x, emb0], axis=-1) \
                @ params["shared_in"].astype(cd)
            out, ck, cv = layer_decode(xin, params["shared"], cfg, gcache["k"],
                                       gcache["v"], pos, kind="full",
                                       constrain=self.constrain)
            x = x + (out - xin)
            stacked = jax.tree.map(lambda *a: jnp.stack(a), *new_ssm)
            return x, {"ssm": stacked, "k": ck, "v": cv}

        x, new_cache = lax.scan(group_body, x, (params["ssm_layers"], cache))
        x = _norm(x, params["final_norm"], cfg)
        logits = jnp.einsum(
            "bsd,vd->bsv", x, params["embed"]["table"].astype(cd),
            preferred_element_type=jnp.float32)[:, 0, :cfg.vocab_size]
        return logits, new_cache

"""Shared neural-net layers (pure JAX, no framework deps).

Parameters are plain pytrees (nested dicts of jnp arrays); init functions
mirror apply functions.  Compute dtype and parameter dtype are decoupled
(mixed-precision policy lives in the config).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _he(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * s).astype(dtype)


def dense_init(key, in_dim, out_shape, dtype, scale=None):
    """Weight (in_dim, *out_shape); fan-in normal init."""
    return _he(key, (in_dim, *out_shape), dtype, scale)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d, dtype):
    return {"scale": jnp.zeros((d,), dtype)}  # gemma-style (1 + scale)


def rmsnorm(x, params, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + params["scale"].astype(jnp.float32))).astype(dt)


def layernorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(x, params, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    x = x * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return x.astype(dt)


# ---------------------------------------------------------------------------
# rotary / sinusoidal position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim, theta):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                        # (D/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n_pos, d, dtype=jnp.float32):
    """Transformer sinusoidal table (used by the whisper encoder)."""
    pos = jnp.arange(n_pos, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, 2.0 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# misc
# ---------------------------------------------------------------------------


def softcap(x, cap):
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return cap * jnp.tanh(x / cap)


def act_fn(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
    }[name]


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------


def mlp_init(key, d, f, dtype, gated=True, bias=False):
    ks = jax.random.split(key, 4)
    p = {"wi": dense_init(ks[0], d, (f,), dtype),
         "wo": dense_init(ks[1], f, (d,), dtype)}
    if gated:
        p["wg"] = dense_init(ks[2], d, (f,), dtype)
    if bias:
        p["bi"] = jnp.zeros((f,), dtype)
        p["bo"] = jnp.zeros((d,), dtype)
    return p


def mlp(x, params, act, compute_dtype, constrain=None):
    """x: (..., d) -> (..., d).  constrain: optional fn applied to the hidden."""
    w = lambda n: params[n].astype(compute_dtype)
    h = x @ w("wi")
    if "bi" in params:
        h = h + w("bi")
    h = act_fn(act)(h)
    if "wg" in params:
        h = h * (x @ w("wg"))
    if constrain is not None:
        h = constrain(h)
    out = h @ w("wo")
    if "bo" in params:
        out = out + w("bo")
    return out


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------


def pad_vocab(v, multiple=128):
    return -(-v // multiple) * multiple


def embed_init(key, vocab, d, dtype, pad_to=128):
    vp = pad_vocab(vocab, pad_to)
    return {"table": (jax.random.normal(key, (vp, d)) * 0.02).astype(dtype)}


def embed_lookup(params, tokens, compute_dtype, scale_by_sqrt_d=False):
    t = params["table"].astype(compute_dtype)
    x = jnp.take(t, tokens, axis=0)
    if scale_by_sqrt_d:
        x = x * jnp.asarray(math.sqrt(t.shape[-1]), compute_dtype)
    return x

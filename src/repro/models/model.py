"""Model factory: family -> model class, plus the pure-SSM decoder."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from .encdec import EncDecModel
from .hybrid import HybridModel
from .layers import embed_init, embed_lookup
from .ssm import ssm_apply, ssm_decode_step, ssm_init
from .transformer import (Constrain, DecoderModel, _dt, _noop, _norm,
                          _norm_init, _remat, chunked_ce)
from typing import TYPE_CHECKING
if TYPE_CHECKING:  # avoid circular import; hints only
    from ..configs.base import ModelConfig


@dataclasses.dataclass
class SSMModel:
    """Attention-free Mamba2 decoder (mamba2-130m family)."""

    cfg: ModelConfig
    constrain: Constrain = _noop

    def init(self, key):
        cfg = self.cfg
        pd = _dt(cfg.param_dtype)
        k_emb, k_layers = jax.random.split(key)
        keys = jax.random.split(k_layers, cfg.n_layers)

        def one(k):
            return {"norm": _norm_init(cfg, pd), "ssm": ssm_init(k, cfg.ssm, pd)}

        return {
            "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, pd),
            "layers": jax.vmap(one)(keys),
            "final_norm": _norm_init(cfg, pd),
        }

    def _cast(self, params, cd):
        return jax.tree.map(
            lambda a: a.astype(cd) if a.dtype == jnp.float32 and a.ndim > 1
            else a, params)

    def loss(self, params, batch):
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        params = self._cast(params, cd)
        x = embed_lookup(params["embed"], batch["tokens"], cd)
        x = self.constrain(x, "act")

        def body(x, p):
            h, _ = ssm_apply(_norm(x, p["norm"], cfg), p["ssm"], cfg.ssm, cd)
            return self.constrain(x + h, "act"), None

        x, _ = lax.scan(lambda c, p: _remat(body, cfg.remat)(c, p),
                        x, params["layers"])
        x = _norm(x, params["final_norm"], cfg)
        nll, n = chunked_ce(x, params["embed"]["table"], batch["labels"], cfg,
                            self.constrain)
        loss = nll / jnp.maximum(n, 1)
        return loss, {"nll": loss}

    def init_cache(self, batch_size: int):
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        s = cfg.ssm
        return {
            "ssm": jnp.zeros((cfg.n_layers, batch_size, s.n_heads, s.head_dim,
                              s.d_state), jnp.float32),
            "conv": jnp.zeros((cfg.n_layers, batch_size, s.d_conv - 1,
                               s.conv_dim), cd),
        }

    def prefill(self, params, batch):
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        params = self._cast(params, cd)
        x = embed_lookup(params["embed"], batch["tokens"], cd)

        def body(x, p):
            h, c = ssm_apply(_norm(x, p["norm"], cfg), p["ssm"], cfg.ssm, cd)
            return x + h, c

        x, cache = lax.scan(body, x, params["layers"])
        x = _norm(x, params["final_norm"], cfg)
        logits = jnp.einsum(
            "bd,vd->bv", x[:, -1], params["embed"]["table"].astype(cd),
            preferred_element_type=jnp.float32)[:, :cfg.vocab_size]
        return logits, cache

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        params = self._cast(params, cd)
        x = embed_lookup(params["embed"], tokens, cd)[:, 0]   # (B, d)

        def body(x, inputs):
            p, c = inputs
            h, c2 = ssm_decode_step(_norm(x, p["norm"], cfg), c, p["ssm"],
                                    cfg.ssm, cd)
            return x + h, c2

        x, new_cache = lax.scan(body, x, (params["layers"], cache))
        x = _norm(x, params["final_norm"], cfg)
        logits = (x @ params["embed"]["table"].astype(cd).T
                  ).astype(jnp.float32)[:, :cfg.vocab_size]
        return logits, new_cache


def build_model(cfg: ModelConfig, constrain: Constrain = _noop):
    return {
        "dense": DecoderModel,
        "moe": DecoderModel,
        "ssm": SSMModel,
        "hybrid": HybridModel,
        "encdec": EncDecModel,
    }[cfg.family](cfg, constrain)

"""Model zoo: dense/SWA/MoE decoders, Mamba2 SSM, hybrid, encoder-decoder."""
from .model import build_model, SSMModel  # noqa: F401
from .transformer import DecoderModel      # noqa: F401
from .hybrid import HybridModel            # noqa: F401
from .encdec import EncDecModel            # noqa: F401

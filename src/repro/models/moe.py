"""Mixture-of-Experts FFN: top-k routing with per-sequence capacity dispatch.

Dispatch/combine are *token-local per batch row* (gather/scatter against an
(E, C) slot table built from a cumulative-position router), so no token ever
crosses a data shard: the only collectives MoE adds are the FSDP/TP param
movements, not token all-to-alls.  Expert weights shard d_ff over the tensor
axis ("TP-MoE"), which is the right regime when per-device token counts are
modest; an EP/all-to-all alternative is explored in §Perf for arctic.

Aux losses: switch-style load-balance loss and router z-loss.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import act_fn, dense_init


class MoESpec(NamedTuple):
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic: parallel dense FFN branch


def moe_init(key, d, f, spec: MoESpec, dtype, gated=True):
    ks = jax.random.split(key, 4)
    E = spec.n_experts
    scale = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, (E,), jnp.float32),  # router in f32
        "wi": (jax.random.normal(ks[1], (E, d, f)) * scale).astype(dtype),
        "wo": (jax.random.normal(ks[2], (E, f, d)) / math.sqrt(f)).astype(dtype),
    }
    if gated:
        p["wg"] = (jax.random.normal(ks[3], (E, d, f)) * scale).astype(dtype)
    return p


def capacity(seq_len: int, spec: MoESpec) -> int:
    return max(1, math.ceil(seq_len * spec.top_k * spec.capacity_factor
                            / spec.n_experts))


def moe_apply(x, params, spec: MoESpec, *, act="silu", compute_dtype=jnp.bfloat16,
              constrain_hidden=None, constrain_in=None, constrain_out=None):
    """x: (B, S, d) -> (out (B, S, d), aux dict with lb_loss / z_loss).

    Routing and slot assignment are per batch row; tokens beyond an expert's
    capacity are dropped (standard switch behavior, capacity_factor slack).
    """
    B, S, d = x.shape
    E, k = spec.n_experts, spec.top_k
    C = capacity(S, spec)
    w = lambda n: params[n].astype(compute_dtype)

    logits = x.astype(jnp.float32) @ params["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(logits, k)               # (B,S,k)
    gate = jax.nn.softmax(top_vals, axis=-1)                   # renormalized

    # ---- aux losses (computed on the full router distribution) ----
    me = jnp.mean(probs, axis=(0, 1))                              # (E,)
    assign_onehot = jax.nn.one_hot(top_idx[..., 0], E)             # top-1 fraction
    ce = jnp.mean(assign_onehot, axis=(0, 1))
    lb_loss = E * jnp.sum(me * ce)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # ---- slot assignment: position of each (token, k) within its expert ----
    # sort-based (§Perf A1): the one-hot cumsum builds a (B, S·k, E) int32
    # tensor — 67 GB/device for arctic train_4k.  argsort + searchsorted
    # computes identical positions with O(B·S·k) memory.
    e_flat = top_idx.reshape(B, S * k)                             # token-major
    order = jnp.argsort(e_flat, axis=-1, stable=True)              # (B,S*k)
    sorted_e = jnp.take_along_axis(e_flat, order, axis=-1)
    starts = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(E), side="left"))(
        sorted_e)                                                   # (B,E)
    pos_sorted = jnp.arange(S * k)[None, :] - jnp.take_along_axis(
        starts, sorted_e, axis=-1)
    inv_order = jnp.argsort(order, axis=-1)
    slot = jnp.take_along_axis(pos_sorted, inv_order, axis=-1)
    keep = slot < C
    slot = jnp.where(keep, slot, C)                                # overflow slot

    # ---- dispatch: (E, C+1) slot table of source-token indices ----
    tok_idx = jnp.broadcast_to(
        (jnp.arange(S)[:, None]).reshape(1, S, 1), (B, S, k)).reshape(B, S * k)

    def build_table(e_row, s_row, t_row):
        tbl = jnp.full((E, C + 1), S, jnp.int32)                   # S -> zero row
        return tbl.at[e_row, s_row].set(t_row, mode="drop")

    table = jax.vmap(build_table)(e_flat, slot, tok_idx)           # (B,E,C+1)
    xp = jnp.concatenate(
        [x, jnp.zeros((B, 1, d), x.dtype)], axis=1)                # zero pad row
    expert_in = jnp.take_along_axis(
        xp[:, None, :, :], table[..., :C, None], axis=2)           # (B,E,C,d)
    if constrain_in is not None:
        expert_in = constrain_in(expert_in)        # EP dispatch all-to-all

    # ---- expert FFN (batched over E; d_ff TP-sharded by the caller) ----
    h = jnp.einsum("becd,edf->becf", expert_in, w("wi"))
    h = act_fn(act)(h)
    if "wg" in params:
        h = h * jnp.einsum("becd,edf->becf", expert_in, w("wg"))
    if constrain_hidden is not None:
        h = constrain_hidden(h)
    out_e = jnp.einsum("becf,efd->becd", h, w("wo"))               # (B,E,C,d)
    if constrain_out is not None:
        # EP combine: all-to-all expert outputs back to batch-major layout
        out_e = constrain_out(out_e)

    # ---- combine: gather each assignment's result, weight, and sum over k ----
    out_flat = jnp.concatenate(
        [out_e, jnp.zeros((B, E, 1, d), out_e.dtype)], axis=2
    ).reshape(B, E * (C + 1), d)
    gather_idx = e_flat * (C + 1) + slot                           # (B,S*k)
    vals = jnp.take_along_axis(out_flat, gather_idx[..., None], axis=1)
    vals = vals * (gate.reshape(B, S * k, 1) * keep[..., None]).astype(vals.dtype)
    out = vals.reshape(B, S, k, d).sum(axis=2)

    aux = {"lb_loss": lb_loss, "z_loss": z_loss,
           "drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return out, aux

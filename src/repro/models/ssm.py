"""Mamba2 (state-space duality) block: chunked-scan training + recurrent decode.

Follows Dao & Gu (2024) SSD with scalar-per-head decay A:

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t ;   y_t = C_t^T h_t + D x_t

Training/prefill uses the chunked dual form: intra-chunk attention-like
quadratic term + inter-chunk state recurrence (a short lax.scan over chunks).
Decode is the O(1) recurrence with a rolling depthwise-conv cache.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .layers import dense_init, rmsnorm


class SSMSpec(NamedTuple):
    d_model: int
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128

    @property
    def d_inner(self):
        return self.expand * self.d_model

    @property
    def n_heads(self):
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self):
        return self.d_inner + 2 * self.d_state  # x, B, C share the conv


def ssm_init(key, spec: SSMSpec, dtype):
    ks = jax.random.split(key, 8)
    di, N, H = spec.d_inner, spec.d_state, spec.n_heads
    proj_out = 2 * di + 2 * N + H    # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], spec.d_model, (proj_out,), dtype),
        "conv_w": (jax.random.normal(ks[1], (spec.d_conv, spec.conv_dim)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((spec.conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dtype),
        "D": jnp.ones((H,), dtype),
        "dt_bias": jnp.log(jnp.expm1(jnp.full((H,), 0.01))).astype(dtype),
        "norm_scale": jnp.zeros((di,), dtype),
        "out_proj": dense_init(ks[2], di, (spec.d_model,), dtype),
    }


def _split_proj(zxbcdt, spec: SSMSpec):
    di, N, H = spec.d_inner, spec.d_state, spec.n_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di:di + spec.conv_dim]
    dt = zxbcdt[..., di + spec.conv_dim:]
    return z, xBC, dt


def _causal_conv(xBC, w, b):
    """Depthwise causal conv, kernel K, via K shifted adds.  xBC: (B, S, C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    S = xBC.shape[1]
    out = sum(pad[:, k:k + S, :] * w[k] for k in range(K))
    return jax.nn.silu(out + b)


def ssm_apply(x, params, spec: SSMSpec, compute_dtype):
    """Training/prefill forward.  x: (B, S, d_model) -> (B, S, d_model).

    Returns (y, final_state) so prefill can seed the decode cache.
    """
    B, S, _ = x.shape
    di, N, H, P = spec.d_inner, spec.d_state, spec.n_heads, spec.head_dim
    Q = min(spec.chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    w = lambda n: params[n].astype(compute_dtype)

    zxbcdt = x @ w("in_proj")
    z, xBC, dt_raw = _split_proj(zxbcdt, spec)
    xBC = _causal_conv(xBC, w("conv_w"), w("conv_b"))
    xs = xBC[..., :di].reshape(B, S, H, P)
    Bm = xBC[..., di:di + N]                                  # (B, S, N), G=1
    Cm = xBC[..., di + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))   # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))               # (H,)
    dA = dt * A                                                      # log-decay

    # chunk views
    xs_c = xs.reshape(B, nc, Q, H, P)
    B_c = Bm.reshape(B, nc, Q, N)
    C_c = Cm.reshape(B, nc, Q, N)
    dt_c = dt.reshape(B, nc, Q, H)
    dA_c = dA.reshape(B, nc, Q, H)
    la = jnp.cumsum(dA_c, axis=2)                                    # (B,nc,Q,H)

    # ---- intra-chunk (dual/attention-like) term, vectorized over chunks ----
    CB = jnp.einsum("bcin,bcjn->bcij", C_c, B_c,
                    preferred_element_type=jnp.float32)              # (B,nc,Q,Q)
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]                # (B,nc,i,j,H)
    iq = jnp.arange(Q)
    causal = iq[:, None] >= iq[None, :]
    att = CB[..., None] * jnp.exp(seg) * dt_c[:, :, None, :, :]      # (B,nc,i,j,H)
    att = jnp.where(causal[None, None, :, :, None], att, 0.0)
    y_diag = jnp.einsum("bcijh,bcjhp->bcihp", att.astype(compute_dtype),
                        xs_c, preferred_element_type=jnp.float32)

    # ---- inter-chunk state recurrence ----
    decay_to_end = jnp.exp(la[:, :, -1:, :] - la)                    # (B,nc,Q,H)
    chunk_state = jnp.einsum("bcjh,bcjn,bcjhp->bchpn",
                             (decay_to_end * dt_c).astype(compute_dtype),
                             B_c, xs_c, preferred_element_type=jnp.float32)
    chunk_decay = jnp.exp(la[:, :, -1, :])                           # (B,nc,H)

    def state_step(s, inputs):
        cs, cd = inputs                                              # (B,H,P,N),(B,H)
        s_new = s * cd[..., None, None] + cs
        return s_new, s                                              # emit state *before* chunk

    init = jnp.zeros((B, H, P, N), jnp.float32)
    final_state, prev_states = lax.scan(
        state_step, init,
        (chunk_state.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)               # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         C_c, prev_states.astype(compute_dtype),
                         jnp.exp(la).astype(compute_dtype),
                         preferred_element_type=jnp.float32)

    y = (y_diag + y_inter).reshape(B, S, H, P)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] \
        * xs.astype(jnp.float32)
    y = y.reshape(B, S, di).astype(compute_dtype)

    # gated RMSNorm + out projection
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, {"scale": params["norm_scale"]}, 1e-5)
    out = y @ w("out_proj")

    conv_tail = xBC_raw_tail(x, params, spec, compute_dtype)
    return out, {"ssm": final_state.astype(jnp.float32), "conv": conv_tail}


def xBC_raw_tail(x, params, spec: SSMSpec, compute_dtype):
    """Last (K-1) pre-conv xBC rows — the decode conv cache after prefill."""
    K = spec.d_conv
    w = lambda n: params[n].astype(compute_dtype)
    tail = x[:, -(K - 1):, :] @ w("in_proj")
    _, xBC, _ = _split_proj(tail, spec)
    return xBC  # (B, K-1, conv_dim)


def ssm_init_cache(batch, spec: SSMSpec, dtype=jnp.float32):
    return {
        "ssm": jnp.zeros((batch, spec.n_heads, spec.head_dim, spec.d_state),
                         jnp.float32),
        "conv": jnp.zeros((batch, spec.d_conv - 1, spec.conv_dim), dtype),
    }


def ssm_decode_step(x, cache, params, spec: SSMSpec, compute_dtype):
    """One-token recurrence.  x: (B, d_model); cache from ssm_init_cache.

    Returns (y (B, d_model), new_cache).
    """
    B, _ = x.shape
    di, N, H, P = spec.d_inner, spec.d_state, spec.n_heads, spec.head_dim
    K = spec.d_conv
    w = lambda n: params[n].astype(compute_dtype)

    zxbcdt = x @ w("in_proj")
    z, xBC_new, dt_raw = _split_proj(zxbcdt, spec)
    # rolling conv window: cache holds previous K-1 raw xBC rows
    window = jnp.concatenate([cache["conv"], xBC_new[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window, w("conv_w")) + w("conv_b")
    xBC = jax.nn.silu(conv_out)
    xv = xBC[:, :di].reshape(B, H, P)
    Bm = xBC[:, di:di + N]
    Cm = xBC[:, di + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))    # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                             # (B,H)

    state = cache["ssm"]                                             # (B,H,P,N) f32
    state = state * dA[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xv.astype(jnp.float32), Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", state, Cm.astype(jnp.float32))
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xv.astype(jnp.float32)
    y = y.reshape(B, di).astype(compute_dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, {"scale": params["norm_scale"]}, 1e-5)
    out = y @ w("out_proj")
    new_cache = {"ssm": state, "conv": window[:, 1:, :]}
    return out, new_cache

"""Decoder-only transformer assembly: init / train-loss / prefill / decode.

Layers are *stacked* (leading n_groups axis) and applied with lax.scan so the
HLO is O(1) in depth; the per-layer body is wrapped in jax.checkpoint per the
config's remat policy.  Heterogeneous layer patterns (gemma-2 local/global
alternation) are expressed as a static ``layer_group`` tuple: the scan runs
over groups, the group body unrolls its members with *static* kinds — so SWA
layers take the O(S·window) slab path, not a masked O(S²) pass.

Sharding is injected via a ``constrain(x, kind)`` callback (see
repro.distributed.sharding) so model code stays mesh-agnostic.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from .attention import (blockwise_attention, decode_attention,
                        packed_causal_attention, swa_attention)
from .layers import (apply_rope, dense_init, embed_init, embed_lookup,
                     layernorm, layernorm_init, mlp, mlp_init, rmsnorm,
                     rmsnorm_init)
from .moe import moe_apply, moe_init
from typing import TYPE_CHECKING
if TYPE_CHECKING:  # avoid circular import; hints only
    from ..configs.base import ModelConfig

Constrain = Callable[[jax.Array, str], jax.Array]
_noop: Constrain = lambda x, kind: x


def _dt(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def _norm_init(cfg: ModelConfig, dtype):
    return (rmsnorm_init if cfg.norm == "rmsnorm" else layernorm_init)(
        cfg.d_model, dtype)


def _norm(x, p, cfg: ModelConfig):
    fn = rmsnorm if cfg.norm == "rmsnorm" else layernorm
    return fn(x, p, cfg.norm_eps)


# ---------------------------------------------------------------------------
# attention sub-block
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig, dtype, d_model=None):
    d = d_model or cfg.d_model
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (H, hd), dtype),
        "wk": dense_init(ks[1], d, (KH, hd), dtype),
        "wv": dense_init(ks[2], d, (KH, hd), dtype),
        "wo": (jax.random.normal(ks[3], (H, hd, d))
               / math.sqrt(H * hd)).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KH, hd), dtype)
        p["bv"] = jnp.zeros((KH, hd), dtype)
    return p


def _qkv(x, p, cfg: ModelConfig, cd, constrain, rope_positions=None):
    w = lambda n: p[n].astype(cd)
    q = jnp.einsum("bsd,dhk->bshk", x, w("wq"))
    k = jnp.einsum("bsd,dhk->bshk", x, w("wk"))
    v = jnp.einsum("bsd,dhk->bshk", x, w("wv"))
    if cfg.qkv_bias:
        q, k, v = q + w("bq"), k + w("bk"), v + w("bv")
    if rope_positions is not None:
        q = apply_rope(q, rope_positions, cfg.rope_theta)
        k = apply_rope(k, rope_positions, cfg.rope_theta)
    return constrain(q, "heads"), constrain(k, "kv_heads"), constrain(v, "kv_heads")


def attn_apply(x, p, cfg: ModelConfig, *, kind: str, constrain: Constrain,
               positions=None, causal=True):
    """Self-attention for train/prefill.  kind: full | local."""
    cd = x.dtype
    B, S, _ = x.shape
    if positions is None and cfg.rope_theta:
        positions = jnp.arange(S)[None, :]
    x = constrain(x, "attn_in")     # §Perf A2: joint batch split before QKV
    q, k, v = _qkv(x, p, cfg, cd, constrain, positions)
    window = cfg.window if kind == "local" else None
    if cfg.attn_impl == "flash":
        from .flash import flash_attention
        out = flash_attention(q, k, v, causal, window, cfg.attn_softcap,
                              cfg.q_block, cfg.k_block, 0)
    elif not causal:
        out = blockwise_attention(q, k, v, causal=False, softcap=cfg.attn_softcap,
                                  q_block=cfg.q_block, k_block=cfg.k_block)
    elif window is not None and S > 2 * window:
        out = swa_attention(q, k, v, window=window, softcap=cfg.attn_softcap,
                            q_block=cfg.q_block)
    elif cfg.attn_impl == "packed" and window is None:
        out = packed_causal_attention(q, k, v, softcap=cfg.attn_softcap,
                                      q_block=cfg.q_block, k_block=cfg.k_block)
    else:
        out = blockwise_attention(q, k, v, causal=True, window=window,
                                  softcap=cfg.attn_softcap,
                                  q_block=cfg.q_block, k_block=cfg.k_block)
    out = constrain(out, "heads")
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))


def attn_prefill_kv(x, p, cfg: ModelConfig, cd, constrain, positions):
    """K/V for cache seeding (rope pre-applied)."""
    _, k, v = _qkv(x, p, cfg, cd, constrain, positions)
    return k, v


def attn_decode(x, p, cfg: ModelConfig, cache_k, cache_v, pos, *, kind: str,
                constrain: Constrain):
    """One-token self-attention.  x: (B,1,d); caches (B,Sc,KH,hd); pos scalar.

    SWA layers use a ring buffer of width == cache length; full layers insert
    at ``pos``.  Returns (out (B,1,d), new_k, new_v).
    """
    cd = x.dtype
    Sc = cache_k.shape[1]
    positions = jnp.full((1, 1), pos)
    q, k, v = _qkv(x, p, cfg, cd, constrain, positions)
    window = cfg.window if kind == "local" else None
    if window is not None and Sc == window:
        slot = pos % window
        eff_pos, eff_window = jnp.minimum(pos + 1, window), None
    else:
        slot = pos
        eff_pos, eff_window = pos + 1, window
    new_k = lax.dynamic_update_slice_in_dim(cache_k, k, slot, axis=1)
    new_v = lax.dynamic_update_slice_in_dim(cache_v, v, slot, axis=1)
    out = decode_attention(q, new_k, new_v, eff_pos, window=eff_window,
                           softcap=cfg.attn_softcap)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return out, new_k, new_v


# ---------------------------------------------------------------------------
# decoder layer (dense or MoE ffn)
# ---------------------------------------------------------------------------


def layer_init(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": _norm_init(cfg, dtype),
        "attn": attn_init(ks[0], cfg, dtype),
        "ln2": _norm_init(cfg, dtype),
    }
    if cfg.moe is not None:
        p["moe"] = moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.moe, dtype,
                            gated=cfg.gated_mlp)
        if cfg.moe.dense_residual:
            p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype,
                                gated=cfg.gated_mlp)
    else:
        p["mlp"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype,
                            gated=cfg.gated_mlp)
    if cfg.post_norms:
        p["ln1_post"] = _norm_init(cfg, dtype)
        p["ln2_post"] = _norm_init(cfg, dtype)
    return p


def _ffn(x, p, cfg: ModelConfig, constrain: Constrain):
    """Dense MLP and/or MoE; returns (y, aux_losses)."""
    aux = {"lb_loss": jnp.zeros((), jnp.float32),
           "z_loss": jnp.zeros((), jnp.float32)}
    y = jnp.zeros_like(x)
    if cfg.moe is not None:
        ym, aux_m = moe_apply(
            x, p["moe"], cfg.moe, act=cfg.act, compute_dtype=x.dtype,
            constrain_hidden=lambda h: constrain(h, "moe_hidden"),
            constrain_in=lambda h: constrain(h, "moe_in"),
            constrain_out=lambda h: constrain(h, "moe_out"))
        y = y + ym
        aux = {"lb_loss": aux_m["lb_loss"], "z_loss": aux_m["z_loss"]}
        if cfg.moe.dense_residual:
            y = y + mlp(x, p["mlp"], cfg.act, x.dtype,
                        constrain=lambda h: constrain(h, "act_ff"))
    else:
        y = mlp(x, p["mlp"], cfg.act, x.dtype,
                constrain=lambda h: constrain(h, "act_ff"))
    return y, aux


def layer_apply(x, p, cfg: ModelConfig, *, kind: str, constrain: Constrain,
                positions=None):
    h = attn_apply(_norm(x, p["ln1"], cfg), p["attn"], cfg, kind=kind,
                   constrain=constrain, positions=positions)
    if cfg.post_norms:
        h = _norm(h, p["ln1_post"], cfg)
    x = constrain(x + h, "act")
    h, aux = _ffn(_norm(x, p["ln2"], cfg), p, cfg, constrain)
    if cfg.post_norms:
        h = _norm(h, p["ln2_post"], cfg)
    return constrain(x + h, "act"), aux


def layer_decode(x, p, cfg: ModelConfig, ck, cv, pos, *, kind: str,
                 constrain: Constrain):
    h, ck, cv = attn_decode(_norm(x, p["ln1"], cfg), p["attn"], cfg, ck, cv,
                            pos, kind=kind, constrain=constrain)
    if cfg.post_norms:
        h = _norm(h, p["ln1_post"], cfg)
    x = x + h
    h, _ = _ffn(_norm(x, p["ln2"], cfg), p, cfg, constrain)
    if cfg.post_norms:
        h = _norm(h, p["ln2_post"], cfg)
    return x + h, ck, cv


# ---------------------------------------------------------------------------
# chunked cross-entropy (never materializes (B, S, V))
# ---------------------------------------------------------------------------


def chunked_ce(h, table, labels, cfg: ModelConfig, constrain: Constrain):
    """h: (B,S,d); table: (Vp, d) output embedding; labels (B,S) (-1 = pad).

    Returns (sum_nll, n_valid).  Scanned in cfg.ce_chunk slices with remat so
    peak logits memory is (B, chunk, V).
    """
    B, S, d = h.shape
    V = cfg.vocab_size
    c = min(cfg.ce_chunk, S)
    assert S % c == 0
    t = table.astype(h.dtype)

    @jax.checkpoint
    def chunk_nll(h_c, y_c):
        logits = jnp.einsum("bsd,vd->bsv", h_c, t,
                            preferred_element_type=jnp.float32)
        logits = constrain(logits, "logits")[..., :V]
        if cfg.final_softcap is not None:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], axis=-1)[..., 0]
        valid = (y_c >= 0)
        nll = jnp.where(valid, lse - picked, 0.0)
        return jnp.sum(nll), jnp.sum(valid)

    def body(carry, xs):
        h_c, y_c = xs
        nll, n = chunk_nll(h_c, y_c)
        return (carry[0] + nll, carry[1] + n), None

    hs = h.reshape(B, S // c, c, d).transpose(1, 0, 2, 3)
    ys = labels.reshape(B, S // c, c).transpose(1, 0, 2)
    (nll, n), _ = lax.scan(body, (jnp.zeros((), jnp.float32),
                                  jnp.zeros((), jnp.int32)), (hs, ys))
    return nll, n


# ---------------------------------------------------------------------------
# decoder-only model
# ---------------------------------------------------------------------------


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(policy)


@dataclasses.dataclass
class DecoderModel:
    """Decoder-only LM (dense / SWA / MoE families)."""

    cfg: ModelConfig
    constrain: Constrain = _noop

    # ---- init ----
    def init(self, key):
        cfg = self.cfg
        pd = _dt(cfg.param_dtype)
        k_emb, k_layers, k_head = jax.random.split(key, 3)
        layer_keys = jax.random.split(k_layers, cfg.n_groups * cfg.group_size)
        layer_keys = layer_keys.reshape(cfg.n_groups, cfg.group_size)
        stacked = jax.vmap(jax.vmap(lambda k: layer_init(k, cfg, pd)))(layer_keys)
        params = {
            "embed": embed_init(k_emb, cfg.vocab_size, cfg.d_model, pd),
            "layers": stacked,
            "final_norm": _norm_init(cfg, pd),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(k_head, cfg.vocab_size, cfg.d_model, pd)
        return params

    # ---- shared trunk ----
    def _embed_in(self, params, batch, cd):
        cfg = self.cfg
        if cfg.input_mode == "embeddings" and "embeddings" in batch:
            return batch["embeddings"].astype(cd)
        return embed_lookup(params["embed"], batch["tokens"], cd,
                            scale_by_sqrt_d=cfg.embed_scale)

    def _trunk(self, params, x, positions):
        cfg = self.cfg

        def group_body(x, gparams):
            aux_sum = jnp.zeros((2,), jnp.float32)
            for j, kind in enumerate(cfg.layer_group):
                pj = jax.tree.map(lambda a: a[j], gparams)
                x, aux = layer_apply(x, pj, cfg, kind=kind,
                                     constrain=self.constrain,
                                     positions=positions)
                aux_sum = aux_sum + jnp.stack([aux["lb_loss"], aux["z_loss"]])
            return x, aux_sum

        body = _remat(group_body, cfg.remat)

        def scan_body(x, gparams):
            return body(x, gparams)

        x, auxes = lax.scan(scan_body, x, params["layers"])
        x = _norm(x, params["final_norm"], cfg)
        return x, jnp.sum(auxes, axis=0)

    def _out_table(self, params):
        return params["embed" if self.cfg.tie_embeddings else "lm_head"]["table"]

    # ---- train ----
    def loss(self, params, batch):
        """batch: tokens/embeddings + labels (-1 ignored).  Returns (loss, metrics)."""
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        params = jax.tree.map(lambda a: a.astype(cd) if a.dtype == jnp.float32
                              and a.ndim > 1 else a, params)
        x = self._embed_in(params, batch, cd)
        x = self.constrain(x, "act")
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        h, aux = self._trunk(params, x, positions)
        nll, n = chunked_ce(h, self._out_table(params), batch["labels"], cfg,
                            self.constrain)
        loss = nll / jnp.maximum(n, 1)
        lb, z = aux[0] / cfg.n_layers, aux[1] / cfg.n_layers
        total = loss + 0.01 * lb + 0.001 * z
        return total, {"nll": loss, "lb_loss": lb, "z_loss": z}

    # ---- serve ----
    def cache_spec(self, batch_size: int, max_len: int):
        """Shapes of the KV cache pytree (per layer kind: SWA ring or full)."""
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        caches = {}
        for j, kind in enumerate(cfg.layer_group):
            span = min(cfg.window, max_len) if kind == "local" and cfg.window \
                else max_len
            caches[f"k{j}"] = jnp.zeros(
                (cfg.n_groups, batch_size, span, cfg.n_kv_heads, cfg.head_dim), cd)
            caches[f"v{j}"] = jnp.zeros_like(caches[f"k{j}"])
        return caches

    def prefill(self, params, batch):
        """Full-sequence forward + cache seeding.  Returns (last_logits, cache)."""
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        params = jax.tree.map(lambda a: a.astype(cd) if a.dtype == jnp.float32
                              and a.ndim > 1 else a, params)
        x = self._embed_in(params, batch, cd)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.arange(S)[None, :]
        cache = self.cache_spec(B, S)

        def group_body(x, inputs):
            gparams, gcache = inputs
            new_c = {}
            for j, kind in enumerate(cfg.layer_group):
                pj = jax.tree.map(lambda a: a[j], gparams)
                xin = _norm(x, pj["ln1"], cfg)
                k, v = attn_prefill_kv(xin, pj["attn"], cfg, cd,
                                       self.constrain, positions)
                span = gcache[f"k{j}"].shape[1]
                new_c[f"k{j}"] = k[:, -span:]
                new_c[f"v{j}"] = v[:, -span:]
                x, _ = layer_apply(x, pj, cfg, kind=kind,
                                   constrain=self.constrain, positions=positions)
            return x, new_c

        body = _remat(group_body, cfg.remat)
        # scan over groups, emitting each group's cache slabs
        def scan_body(x, inputs):
            return body(x, inputs)

        x, caches = lax.scan(scan_body, x, (params["layers"], cache))
        x = _norm(x, params["final_norm"], cfg)
        logits = jnp.einsum("bd,vd->bv", x[:, -1].astype(cd),
                            self._out_table(params).astype(cd),
                            preferred_element_type=jnp.float32)
        logits = logits[..., :cfg.vocab_size]
        if cfg.final_softcap is not None:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits, caches

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B, 1) int32; pos: scalar int32 position of this token.

        Returns (logits (B, V), new_cache).
        """
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        params = jax.tree.map(lambda a: a.astype(cd) if a.dtype == jnp.float32
                              and a.ndim > 1 else a, params)
        x = embed_lookup(params["embed"], tokens, cd,
                         scale_by_sqrt_d=cfg.embed_scale)
        x = self.constrain(x, "act")

        def group_body(x, inputs):
            gparams, gcache = inputs
            new_c = dict(gcache)
            for j, kind in enumerate(cfg.layer_group):
                pj = jax.tree.map(lambda a: a[j], gparams)
                x, ck, cv = layer_decode(x, pj, cfg, gcache[f"k{j}"],
                                         gcache[f"v{j}"], pos, kind=kind,
                                         constrain=self.constrain)
                new_c[f"k{j}"], new_c[f"v{j}"] = ck, cv
            return x, new_c

        x, new_cache = lax.scan(group_body, x, (params["layers"], cache))
        x = _norm(x, params["final_norm"], cfg)
        logits = jnp.einsum("bsd,vd->bsv", x, self._out_table(params).astype(cd),
                            preferred_element_type=jnp.float32)[:, 0, :cfg.vocab_size]
        if cfg.final_softcap is not None:
            logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
        return logits, new_cache

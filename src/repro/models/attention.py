"""Attention: blockwise (flash-style) training/prefill paths + KV-cache decode.

Three training/prefill implementations, selected by config:

* ``blockwise``  — online-softmax over (q-block × kv-block) tiles, O(S·block)
  activation memory.  Causal/window masking is applied per tile; fully-masked
  tiles still cost FLOPs (the HLO-vs-useful gap is tracked in §Roofline).
* ``packed``     — causal-exact variant: only tiles with ki <= qi are
  evaluated (a static lower-triangular tile schedule), halving attention
  FLOPs for long sequences.  Used as a §Perf hillclimb lever.
* ``swa``        — sliding-window: per q-block, a (window + q_block)-wide kv
  slab is dynamically sliced, making FLOPs O(S·window) instead of O(S²).

All paths support GQA (q heads grouped over kv heads), attention-logit
soft-capping (gemma-2), and bidirectional mode (whisper encoder).
"""
from __future__ import annotations


import jax.numpy as jnp
from jax import lax

NEG_INF = -1.0e30


def _tile_attn(qblk, kblk, vblk, mask, scale, cap):
    """One online-softmax tile.  qblk: (B, qb, KH, G, D); k/v: (B, kb, KH, D).

    Returns (row_max (B,KH,G,qb), p_sum, pv (B,KH,G,qb,D)) in f32.
    """
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                   preferred_element_type=jnp.float32) * scale
    if cap is not None:
        s = cap * jnp.tanh(s / cap)
    s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.where(mask, jnp.exp(s - m[..., None]), 0.0)
    pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                    preferred_element_type=jnp.float32)
    return m, jnp.sum(p, axis=-1), pv


def _merge(m, l, acc, m2, l2, pv):
    m_new = jnp.maximum(m, m2)
    a1 = jnp.exp(m - m_new)
    a2 = jnp.exp(m2 - m_new)
    return m_new, l * a1 + l2 * a2, acc * a1[..., None] + pv * a2[..., None]


def _finish(l, acc, B, qb, KH, G, D, dtype):
    out = acc / jnp.maximum(l, 1e-37)[..., None]        # (B,KH,G,qb,D)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, qb, KH * G, D).astype(dtype)


def _grouped(q, k):
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    assert H % KH == 0, (H, KH)
    return q.reshape(B, Sq, KH, H // KH, D), H // KH


def blockwise_attention(
    q, k, v, *, causal=True, window=None, softcap=None,
    q_block=512, k_block=512, q_offset=0,
):
    """Masked blockwise attention.  q: (B,Sq,H,D), k/v: (B,Sk,KH,D).

    ``q_offset``: global position of q[0] (for prefill continuation).
    Sequence lengths must be multiples of the block sizes (configs ensure it).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    qb, kb = min(q_block, Sq), min(k_block, Sk)
    nq, nk = Sq // qb, Sk // kb
    qg, G = _grouped(q, k)
    KH = k.shape[2]
    scale = D ** -0.5
    qs = qg.reshape(B, nq, qb, KH, G, D).transpose(1, 0, 2, 3, 4, 5)
    iq = jnp.arange(qb)
    ik = jnp.arange(kb)

    def per_q(qi, qblk):
        def kv_step(carry, ki):
            m, l, acc = carry
            kblk = lax.dynamic_slice_in_dim(k, ki * kb, kb, axis=1)
            vblk = lax.dynamic_slice_in_dim(v, ki * kb, kb, axis=1)
            qpos = q_offset + qi * qb + iq[:, None]
            kpos = ki * kb + ik[None, :]
            mask = jnp.ones((qb, kb), bool)
            if causal:
                mask &= qpos >= kpos
            if window is not None:
                mask &= kpos > qpos - window
            carry = _merge(m, l, acc, *_tile_attn(qblk, kblk, vblk, mask, scale, softcap))
            return carry, None

        m0 = jnp.full((B, KH, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KH, G, qb, D), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        return _finish(l, acc, B, qb, KH, G, D, q.dtype)

    out = lax.map(lambda args: per_q(*args), (jnp.arange(nq), qs))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)


def packed_causal_attention(
    q, k, v, *, softcap=None, q_block=512, k_block=512,
):
    """Causal attention evaluating only tiles with ki <= qi (exact FLOPs).

    Requires Sq == Sk (self-attention prefill/training).  ~2× fewer attention
    FLOPs than the masked blockwise path at large S.
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    assert Sq == Sk, "packed path is for self-attention"
    qb, kb = min(q_block, Sq), min(k_block, Sk)
    assert qb == kb, "packed path uses square tiles"
    n = Sq // qb
    qg, G = _grouped(q, k)
    KH = k.shape[2]
    scale = D ** -0.5
    # static lower-triangular tile schedule, row-major per q block
    pairs = [(qi, ki) for qi in range(n) for ki in range(qi + 1)]
    qis = jnp.array([p[0] for p in pairs])
    kis = jnp.array([p[1] for p in pairs])
    iq = jnp.arange(qb)
    ik = jnp.arange(kb)

    def step(carry, s):
        m, l, acc, out = carry
        qi, ki = qis[s], kis[s]
        is_first = ki == 0
        m = jnp.where(is_first, NEG_INF, m)
        l = jnp.where(is_first, 0.0, l)
        acc = jnp.where(is_first, 0.0, acc)
        qblk = lax.dynamic_slice_in_dim(qg, qi * qb, qb, axis=1)
        kblk = lax.dynamic_slice_in_dim(k, ki * kb, kb, axis=1)
        vblk = lax.dynamic_slice_in_dim(v, ki * kb, kb, axis=1)
        diag = qi == ki
        mask = jnp.where(diag, iq[:, None] >= ik[None, :], True)
        m, l, acc = _merge(m, l, acc,
                           *_tile_attn(qblk, kblk, vblk, mask, scale, softcap))
        done = _finish(l, acc, B, qb, KH, G, D, q.dtype)    # (B,qb,H,D)
        out = jnp.where(
            diag,  # segment complete -> commit this q block
            lax.dynamic_update_slice_in_dim(out, done, qi * qb, axis=1),
            out)
        return (m, l, acc, out), None

    m0 = jnp.full((B, KH, G, qb), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KH, G, qb), jnp.float32)
    a0 = jnp.zeros((B, KH, G, qb, D), jnp.float32)
    o0 = jnp.zeros((B, Sq, H, D), q.dtype)
    (_, _, _, out), _ = lax.scan(step, (m0, l0, a0, o0), jnp.arange(len(pairs)))
    return out


def swa_attention(
    q, k, v, *, window, softcap=None, q_block=512, q_offset=0,
):
    """Sliding-window causal attention with O(S·window) FLOPs.

    Per q block, slices a (window + q_block)-wide kv slab ending at the
    block's last row.  Assumes Sq == Sk (training/prefill).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    qb = min(q_block, Sq)
    nq = Sq // qb
    slab = min(Sk, window + qb)
    qg, G = _grouped(q, k)
    KH = k.shape[2]
    scale = D ** -0.5
    qs = qg.reshape(B, nq, qb, KH, G, D).transpose(1, 0, 2, 3, 4, 5)
    iq = jnp.arange(qb)
    ik = jnp.arange(slab)

    def per_q(qi, qblk):
        q_end = q_offset + (qi + 1) * qb            # one past last q position
        start = jnp.clip(q_end - slab, 0, Sk - slab)
        kblk = lax.dynamic_slice_in_dim(k, start, slab, axis=1)
        vblk = lax.dynamic_slice_in_dim(v, start, slab, axis=1)
        qpos = q_offset + qi * qb + iq[:, None]
        kpos = start + ik[None, :]
        mask = (qpos >= kpos) & (kpos > qpos - window)
        m, l, pv = _tile_attn(qblk, kblk, vblk, mask, scale, softcap)
        return _finish(l, pv, B, qb, KH, G, D, q.dtype)

    out = lax.map(lambda args: per_q(*args), (jnp.arange(nq), qs))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)


def decode_attention(
    q, k_cache, v_cache, pos, *, window=None, softcap=None,
):
    """Single-token decode vs a (possibly window-limited) KV cache.

    q: (B, 1, H, D); caches: (B, S_cache, KH, D); pos: scalar or (B,) current
    position (number of valid cache entries, *including* this step's token
    already inserted by the caller).
    """
    B, _, H, D = q.shape
    Sk = k_cache.shape[1]
    qg, G = _grouped(q, k_cache)
    KH = k_cache.shape[2]
    scale = D ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    kpos = jnp.arange(Sk)
    pos = jnp.asarray(pos)
    pos_b = pos.reshape(-1, 1) if pos.ndim else pos[None, None]
    valid = kpos[None, :] < pos_b                     # (B or 1, Sk)
    if window is not None:
        valid &= kpos[None, :] > pos_b - 1 - window   # last `window` entries
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, 1, H, D).astype(q.dtype)


def attention(
    q, k, v, *, impl="blockwise", causal=True, window=None, softcap=None,
    q_block=512, k_block=512,
):
    """Dispatch by implementation name (training/prefill)."""
    if impl == "packed" and causal and window is None and q.shape[1] == k.shape[1]:
        return packed_causal_attention(
            q, k, v, softcap=softcap, q_block=q_block, k_block=k_block)
    if impl == "swa" or (window is not None and q.shape[1] > 2 * (window or 0)):
        if window is not None and causal:
            return swa_attention(
                q, k, v, window=window, softcap=softcap, q_block=q_block)
    return blockwise_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        q_block=q_block, k_block=k_block)

"""Encoder-decoder transformer (whisper family).

The audio frontend (log-mel + conv downsampling) is a STUB per the assignment:
``input_specs`` provides precomputed frame embeddings (B, S_enc, d) directly.
Encoder: bidirectional self-attention + sinusoidal positions.
Decoder: causal self-attention (KV cache for decode), cross-attention to the
encoder output (cross K/V precomputed once at prefill), learned positions.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from .attention import decode_attention
from .flash import flash_attention
from .layers import (embed_init, embed_lookup, mlp, mlp_init,
                     sinusoidal_positions)
from .transformer import (Constrain, _dt, _noop, _norm, _norm_init, _remat,
                          attn_init, chunked_ce, _qkv)
from typing import TYPE_CHECKING
if TYPE_CHECKING:  # avoid circular import; hints only
    from ..configs.base import ModelConfig


def _cross_init(key, cfg: ModelConfig, dtype):
    return attn_init(key, cfg, dtype)


def _cross_kv(enc, p, cfg, cd, constrain):
    w = lambda n: p[n].astype(cd)
    k = jnp.einsum("bsd,dhk->bshk", enc, w("wk"))
    v = jnp.einsum("bsd,dhk->bshk", enc, w("wv"))
    if cfg.qkv_bias:
        k, v = k + w("bk"), v + w("bv")
    return constrain(k, "kv_heads"), constrain(v, "kv_heads")


def _cross_apply(x, kc, vc, p, cfg, cd, constrain):
    w = lambda n: p[n].astype(cd)
    q = jnp.einsum("bsd,dhk->bshk", x, w("wq"))
    if cfg.qkv_bias:
        q = q + w("bq")
    out = flash_attention(q, kc, vc, False, None, None,
                          cfg.q_block, cfg.k_block, 0)
    return jnp.einsum("bshk,hkd->bsd", out, w("wo"))


def _cross_decode(x, kc, vc, p, cfg, cd):
    w = lambda n: p[n].astype(cd)
    q = jnp.einsum("bsd,dhk->bshk", x, w("wq"))
    if cfg.qkv_bias:
        q = q + w("bq")
    out = decode_attention(q, kc, vc, kc.shape[1])
    return jnp.einsum("bshk,hkd->bsd", out, w("wo"))


@dataclasses.dataclass
class EncDecModel:
    cfg: ModelConfig
    constrain: Constrain = _noop

    def init(self, key):
        cfg = self.cfg
        pd = _dt(cfg.param_dtype)
        ks = jax.random.split(key, 6)

        def enc_layer(k):
            k1, k2 = jax.random.split(k)
            return {
                "ln1": _norm_init(cfg, pd),
                "attn": attn_init(k1, cfg, pd),
                "ln2": _norm_init(cfg, pd),
                "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, pd,
                                gated=cfg.gated_mlp, bias=True),
            }

        def dec_layer(k):
            k1, k2, k3 = jax.random.split(k, 3)
            return {
                "ln1": _norm_init(cfg, pd),
                "attn": attn_init(k1, cfg, pd),
                "ln_x": _norm_init(cfg, pd),
                "xattn": _cross_init(k2, cfg, pd),
                "ln2": _norm_init(cfg, pd),
                "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, pd,
                                gated=cfg.gated_mlp, bias=True),
            }

        enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
        dec_keys = jax.random.split(ks[1], cfg.n_layers)
        return {
            "embed": embed_init(ks[2], cfg.vocab_size, cfg.d_model, pd),
            "pos_table": (jax.random.normal(ks[3], (cfg.pos_table_len,
                                                    cfg.d_model)) * 0.01).astype(pd),
            "enc_layers": jax.vmap(enc_layer)(enc_keys),
            "enc_norm": _norm_init(cfg, pd),
            "dec_layers": jax.vmap(dec_layer)(dec_keys),
            "final_norm": _norm_init(cfg, pd),
        }

    def _cast(self, params, cd):
        return jax.tree.map(
            lambda a: a.astype(cd) if a.dtype == jnp.float32 and a.ndim > 1
            else a, params)

    # ---- encoder ----
    def encode(self, params, enc_embeddings):
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        x = enc_embeddings.astype(cd)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model, cd)[None]
        x = self.constrain(x, "act")

        def body(x, p):
            w = lambda n, pp=p: pp[n]
            h = _norm(x, p["ln1"], cfg)
            q, k, v = _qkv(h, p["attn"], cfg, cd, self.constrain, None)
            h = flash_attention(q, k, v, False, None, None,
                                cfg.q_block, cfg.k_block, 0)
            h = jnp.einsum("bshk,hkd->bsd", h, p["attn"]["wo"].astype(cd))
            x = self.constrain(x + h, "act")
            h = mlp(_norm(x, p["ln2"], cfg), p["mlp"], cfg.act, cd,
                    constrain=lambda t: self.constrain(t, "act_ff"))
            return self.constrain(x + h, "act"), None

        x, _ = lax.scan(lambda c, p: _remat(body, cfg.remat)(c, p),
                        x, params["enc_layers"])
        return _norm(x, params["enc_norm"], cfg)

    # ---- decoder trunk (train) ----
    def _dec_embed(self, params, tokens, cd, pos0=0):
        cfg = self.cfg
        x = embed_lookup(params["embed"], tokens, cd)
        S = tokens.shape[1]
        pos = params["pos_table"].astype(cd)[pos0:pos0 + S]
        return x + pos[None]

    def loss(self, params, batch):
        """batch: enc_embeddings (B,S_enc,d), tokens (B,S), labels (B,S)."""
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        params = self._cast(params, cd)
        enc = self.encode(params, batch["enc_embeddings"])
        x = self._dec_embed(params, batch["tokens"], cd)
        x = self.constrain(x, "act")

        def body(x, p):
            h = _norm(x, p["ln1"], cfg)
            q, k, v = _qkv(h, p["attn"], cfg, cd, self.constrain, None)
            h = flash_attention(q, k, v, True, None, None,
                                cfg.q_block, cfg.k_block, 0)
            h = jnp.einsum("bshk,hkd->bsd", h, p["attn"]["wo"].astype(cd))
            x = self.constrain(x + h, "act")
            kc, vc = _cross_kv(enc, p["xattn"], cfg, cd, self.constrain)
            h = _cross_apply(_norm(x, p["ln_x"], cfg), kc, vc, p["xattn"],
                             cfg, cd, self.constrain)
            x = self.constrain(x + h, "act")
            h = mlp(_norm(x, p["ln2"], cfg), p["mlp"], cfg.act, cd,
                    constrain=lambda t: self.constrain(t, "act_ff"))
            return self.constrain(x + h, "act"), None

        x, _ = lax.scan(lambda c, p: _remat(body, cfg.remat)(c, p),
                        x, params["dec_layers"])
        x = _norm(x, params["final_norm"], cfg)
        nll, n = chunked_ce(x, params["embed"]["table"], batch["labels"], cfg,
                            self.constrain)
        loss = nll / jnp.maximum(n, 1)
        return loss, {"nll": loss}

    # ---- serve ----
    def init_cache(self, batch_size: int, max_len: int, enc_len: int):
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        L, B = cfg.n_layers, batch_size
        kv = lambda s: jnp.zeros((L, B, s, cfg.n_kv_heads, cfg.head_dim), cd)
        return {"k": kv(max_len), "v": kv(max_len),
                "xk": kv(enc_len), "xv": kv(enc_len)}

    def prefill(self, params, batch, max_decode_len: int = 256):
        """Encode + seed cross K/V; decoder starts empty (autoregressive from BOS)."""
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        params = self._cast(params, cd)
        enc = self.encode(params, batch["enc_embeddings"])
        B = enc.shape[0]
        max_len = max_decode_len

        def per_layer(p):
            return _cross_kv(enc, p["xattn"], cfg, cd, self.constrain)

        xk, xv = jax.vmap(per_layer)(params["dec_layers"])
        cache = self.init_cache(B, max_len, enc.shape[1])
        cache["xk"], cache["xv"] = xk, xv
        logits = jnp.zeros((B, cfg.vocab_size), jnp.float32)
        return logits, cache

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        cd = _dt(cfg.compute_dtype)
        params = self._cast(params, cd)
        x = self._dec_embed_dyn(params, tokens, cd, pos)
        x = self.constrain(x, "act")

        def body(x, inputs):
            p, ck, cv, xk, xv = inputs
            h = _norm(x, p["ln1"], cfg)
            q, k, v = _qkv(h, p["attn"], cfg, cd, self.constrain, None)
            ck = lax.dynamic_update_slice_in_dim(ck, k, pos, axis=1)
            cv = lax.dynamic_update_slice_in_dim(cv, v, pos, axis=1)
            h = decode_attention(q, ck, cv, pos + 1)
            h = jnp.einsum("bshk,hkd->bsd", h, p["attn"]["wo"].astype(cd))
            x = x + h
            h = _cross_decode(_norm(x, p["ln_x"], cfg), xk, xv, p["xattn"],
                              cfg, cd)
            x = x + h
            h = mlp(_norm(x, p["ln2"], cfg), p["mlp"], cfg.act, cd)
            return x + h, (ck, cv)

        x, (ks, vs) = lax.scan(
            body, x, (params["dec_layers"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        new_cache = dict(cache, k=ks, v=vs)
        x = _norm(x, params["final_norm"], cfg)
        logits = jnp.einsum(
            "bsd,vd->bsv", x, params["embed"]["table"].astype(cd),
            preferred_element_type=jnp.float32)[:, 0, :cfg.vocab_size]
        return logits, new_cache

    def _dec_embed_dyn(self, params, tokens, cd, pos):
        x = embed_lookup(params["embed"], tokens, cd)
        p = lax.dynamic_slice_in_dim(params["pos_table"].astype(cd), pos, 1)
        return x + p[None]

"""Flash attention with a custom VJP (no O(S²) residuals).

jax.grad of the scan-based blockwise attention saves every (qb × kb)
probability tile for the backward pass — the dry-run showed f32
[nq, nk, B, KH, G, qb, kb] temporaries dominating both HBM traffic and peak
memory (EXPERIMENTS.md §Perf, iteration L1).  This module implements the
standard flash backward instead:

* forward saves only (q, k, v, out, lse) — O(S·D);
* backward recomputes tiles in two passes:
    pass A: per q-block  -> dq   (inner scan over kv blocks)
    pass B: per kv-block -> dk,dv (inner scan over q blocks)
  Two recompute passes trade ~1.4× extra attention FLOPs for removing all
  large carries — on TPU the compute term is far from the roof while memory
  dominates, so this is the right trade (hypothesis/measurement in §Perf).

Supports causal masking, sliding windows (O(S·window) via slab slicing),
GQA, and gemma-2 logit soft-capping.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1.0e30


def _mask(qpos, kpos, causal, window):
    m = jnp.ones(jnp.broadcast_shapes(qpos.shape, kpos.shape), bool)
    if causal:
        m &= qpos >= kpos
    if window is not None:
        m &= kpos > qpos - window
    return m


def _scores(qblk, kblk, scale, softcap):
    """(B,qb,KH,G,D) x (B,kb,KH,D) -> f32 (B,KH,G,qb,kb); returns (s, gate)
    where gate is d(s)/d(s_hat) for the softcap chain (None if no cap)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kblk,
                   preferred_element_type=jnp.float32) * scale
    if softcap is None:
        return s, None
    t = jnp.tanh(s / softcap)
    return softcap * t, (1.0 - t * t)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal=True, window=None, softcap=None,
                    q_block=512, k_block=512, q_offset=0):
    """q: (B,Sq,H,D); k/v: (B,Sk,KH,D) -> (B,Sq,H,D)."""
    out, _ = _fwd(q, k, v, causal, window, softcap, q_block, k_block, q_offset)
    return out


def _fwd(q, k, v, causal, window, softcap, q_block, k_block, q_offset):
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    KH = k.shape[2]
    G = H // KH
    qb = min(q_block, Sq)
    kb = min(k_block, Sk)
    nq = Sq // qb
    scale = D ** -0.5
    qg = q.reshape(B, Sq, KH, G, D)
    use_slab = window is not None and causal and Sk > window + qb
    slab = min(Sk, -(-(window + qb) // kb) * kb) if use_slab else Sk
    nk = slab // kb
    masked = causal or window is not None     # W3: skip selects when all-True
    iq = jnp.arange(qb)
    ik = jnp.arange(kb)

    def per_q(qi):
        qblk = lax.dynamic_slice_in_dim(qg, qi * qb, qb, axis=1)
        if use_slab:
            start = jnp.clip(q_offset + (qi + 1) * qb - slab, 0, Sk - slab)
        else:
            start = 0

        def kv_step(carry, kj):
            m, l, acc = carry
            k0 = start + kj * kb
            kblk = lax.dynamic_slice_in_dim(k, k0, kb, axis=1)
            vblk = lax.dynamic_slice_in_dim(v, k0, kb, axis=1)
            s, _ = _scores(qblk, kblk, scale, softcap)
            if masked:
                qpos = q_offset + qi * qb + iq[:, None]
                kpos = k0 + ik[None, :]
                msk = _mask(qpos, kpos, causal, window)
                s = jnp.where(msk, s, NEG_INF)
                m2 = jnp.max(s, axis=-1)
                p = jnp.where(msk, jnp.exp(s - m2[..., None]), 0.0)
            else:
                m2 = jnp.max(s, axis=-1)
                p = jnp.exp(s - m2[..., None])
            l2 = jnp.sum(p, axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                            preferred_element_type=jnp.float32)
            m_new = jnp.maximum(m, m2)
            a1, a2 = jnp.exp(m - m_new), jnp.exp(m2 - m_new)
            return (m_new, l * a1 + l2 * a2,
                    acc * a1[..., None] + pv * a2[..., None]), None

        m0 = jnp.full((B, KH, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, qb), jnp.float32)
        a0 = jnp.zeros((B, KH, G, qb, D), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        o = acc / jnp.maximum(l, 1e-37)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-37))
        return (o.transpose(0, 3, 1, 2, 4).reshape(B, qb, H, D).astype(q.dtype),
                lse)

    outs, lses = lax.map(per_q, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, D)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, KH, G, Sq)  # (nq,B,KH,G,qb)
    return out, (q, k, v, out, lse)


def _bwd(causal, window, softcap, q_block, k_block, q_offset, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    KH = k.shape[2]
    G = H // KH
    qb = min(q_block, Sq)
    kb = min(k_block, Sk)
    nq, nk_full = Sq // qb, Sk // kb
    scale = D ** -0.5
    qg = q.reshape(B, Sq, KH, G, D)
    dog = dout.reshape(B, Sq, KH, G, D)
    og = out.reshape(B, Sq, KH, G, D)
    # delta_i = sum_d dout_i * out_i  (flash backward row term)
    delta = jnp.einsum("bshgd,bshgd->bhgs", dog.astype(jnp.float32),
                       og.astype(jnp.float32))
    use_slab = window is not None and causal and Sk > window + qb
    slab = min(Sk, -(-(window + qb) // kb) * kb) if use_slab else Sk
    nk = slab // kb if use_slab else nk_full
    masked = causal or window is not None     # W3: skip selects when all-True
    iq = jnp.arange(qb)
    ik = jnp.arange(kb)

    def tile_grads(qblk, kblk, vblk, lse_blk, delta_blk, do_blk, qpos, kpos):
        """Recompute p and return (ds_hat f32 (B,KH,G,qb,kb), p)."""
        s, gate = _scores(qblk, kblk, scale, softcap)
        if masked:
            msk = _mask(qpos, kpos, causal, window)
            p = jnp.where(msk, jnp.exp(s - lse_blk[..., None]), 0.0)
        else:
            p = jnp.exp(s - lse_blk[..., None])
        dp = jnp.einsum("bqhgd,bkhd->bhgqk", do_blk, vblk,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - delta_blk[..., None])
        if gate is not None:
            ds = ds * gate
        return ds, p

    # ---- pass A: dq per q block ----
    def per_q(qi):
        qblk = lax.dynamic_slice_in_dim(qg, qi * qb, qb, axis=1)
        do_blk = lax.dynamic_slice_in_dim(dog, qi * qb, qb, axis=1)
        lse_blk = lax.dynamic_slice_in_dim(lse, qi * qb, qb, axis=3)
        delta_blk = lax.dynamic_slice_in_dim(delta, qi * qb, qb, axis=3)
        start = jnp.clip(q_offset + (qi + 1) * qb - slab, 0, Sk - slab) \
            if use_slab else 0

        def kv_step(dq, kj):
            k0 = start + kj * kb
            kblk = lax.dynamic_slice_in_dim(k, k0, kb, axis=1)
            vblk = lax.dynamic_slice_in_dim(v, k0, kb, axis=1)
            qpos = q_offset + qi * qb + iq[:, None]
            kpos = k0 + ik[None, :]
            ds, _ = tile_grads(qblk, kblk, vblk, lse_blk, delta_blk, do_blk,
                               qpos, kpos)
            dq = dq + jnp.einsum("bhgqk,bkhd->bqhgd", ds.astype(kblk.dtype),
                                 kblk, preferred_element_type=jnp.float32)
            return dq, None

        dq0 = jnp.zeros((B, qb, KH, G, D), jnp.float32)
        dq, _ = lax.scan(kv_step, dq0, jnp.arange(nk))
        return (dq * scale).astype(q.dtype)

    dqs = lax.map(per_q, jnp.arange(nq))             # (nq, B, qb, KH, G, D)
    dq = dqs.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, H, D)

    # ---- pass B: dk, dv per kv block ----
    # q range attending to kv block j: [j*kb, j*kb + window + qb) for SWA,
    # else all q blocks (masked).
    if use_slab:
        nq_b = -(-(window + kb) // qb)            # ceil; edges masked
        q_slab = min(Sq, nq_b * qb)
        nq_b = q_slab // qb
    else:
        q_slab, nq_b = Sq, nq

    def per_k(kj):
        k0 = kj * kb
        kblk = lax.dynamic_slice_in_dim(k, k0, kb, axis=1)
        vblk = lax.dynamic_slice_in_dim(v, k0, kb, axis=1)
        qstart = jnp.clip(k0 - q_offset, 0, Sq - q_slab) if use_slab else 0

        def q_step(carry, qi):
            dk, dv = carry
            qpos0 = qstart + qi * qb
            qblk = lax.dynamic_slice_in_dim(qg, qpos0, qb, axis=1)
            do_blk = lax.dynamic_slice_in_dim(dog, qpos0, qb, axis=1)
            lse_blk = lax.dynamic_slice_in_dim(lse, qpos0, qb, axis=3)
            delta_blk = lax.dynamic_slice_in_dim(delta, qpos0, qb, axis=3)
            qpos = q_offset + qpos0 + iq[:, None]
            kpos = k0 + ik[None, :]
            ds, p = tile_grads(qblk, kblk, vblk, lse_blk, delta_blk, do_blk,
                               qpos, kpos)
            dk = dk + jnp.einsum("bhgqk,bqhgd->bkhd", ds.astype(qblk.dtype),
                                 qblk, preferred_element_type=jnp.float32)
            dv = dv + jnp.einsum("bhgqk,bqhgd->bkhd", p.astype(do_blk.dtype),
                                 do_blk, preferred_element_type=jnp.float32)
            return (dk, dv), None

        dk0 = jnp.zeros((B, kb, KH, D), jnp.float32)
        dv0 = jnp.zeros((B, kb, KH, D), jnp.float32)
        (dk, dv), _ = lax.scan(q_step, (dk0, dv0), jnp.arange(nq_b))
        return (dk * scale).astype(k.dtype), dv.astype(v.dtype)

    dks, dvs = lax.map(per_k, jnp.arange(nk_full))   # (nk, B, kb, KH, D)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KH, D)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, Sk, KH, D)
    return dq, dk, dv


def _fwd_rule(q, k, v, causal, window, softcap, q_block, k_block, q_offset):
    out, res = _fwd(q, k, v, causal, window, softcap, q_block, k_block,
                    q_offset)
    return out, res


flash_attention.defvjp(_fwd_rule, _bwd)

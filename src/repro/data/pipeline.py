"""Deterministic, shardable synthetic data pipeline.

Batches are a pure function of (seed, step) via the counter-based event
stream (core/events.py), so:
* every host/shard can produce exactly its slice without coordination;
* recovery replays batch t bit-identically after restart (train/fault.py);
* the Δ-window scheduler can defer a worker's microbatch and fetch it later.

The token stream is Zipf-like over the vocab with a shifted-label LM
objective.  A background-thread prefetcher overlaps host batch assembly
with device compute.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import jax.numpy as jnp
import numpy as np

from ..core.events import counter_bits


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 1234
    zipf_alpha: float = 1.1


def _zipf_map(u: np.ndarray, vocab: int, alpha: float) -> np.ndarray:
    """Map uniform [0,1) to bounded-Zipf ranks over [0, vocab).

    Inverse CDF of p(r) ∝ r^-alpha on r ∈ [1, V] (continuous approximation):
    r = (1 + u·(V^{1-α} − 1))^{1/(1-α)}.
    """
    one_m_a = 1.0 - alpha
    r = (1.0 + u * (vocab ** one_m_a - 1.0)) ** (1.0 / one_m_a)
    return np.clip(r - 1.0, 0, vocab - 1).astype(np.int32)


def make_batch(cfg: DataConfig, step: int) -> dict:
    """Batch t as a pure function of (seed, step): tokens + shifted labels."""
    bits = counter_bits(
        np.uint32(cfg.seed), jnp.uint32(step),
        jnp.arange(cfg.global_batch, dtype=jnp.int32)[:, None],
        jnp.arange(cfg.seq_len + 1, dtype=jnp.int32)[None, :])
    u = np.asarray(bits[..., 0], dtype=np.float64) / 2.0**32
    toks = _zipf_map(u, cfg.vocab_size, cfg.zipf_alpha)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:])}


class Prefetcher:
    """Background-thread batch prefetch with a bounded queue."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        s = self._step
        while not self._stop.is_set():
            try:
                self._q.put(make_batch(self.cfg, s), timeout=0.2)
                s += 1
            except queue.Full:
                continue

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)

"""Render and validate metrics snapshots and Chrome traces.

``python -m repro.obs summarize [--check] PATH...`` turns the files the
telemetry layer writes — ``metrics.json`` / ``metrics.prom`` snapshot
dirs, JSONL metric sinks, Chrome-trace JSONs — into the human text table
the service CLI's one-line summary approximates, and (with ``--check``)
validates them for CI:

* a metrics snapshot must be non-empty, and if it came from the sweep
  service (any ``repro_service_*`` series) it must contain live paper
  observables — the :data:`REQUIRED_SERVICE_SERIES` — with at least one
  histogram observation each;
* a trace must be non-empty and its spans must nest correctly per
  ``(pid, tid)`` lane (proper bracketing; overlap without containment is
  a corrupt trace).

File kind is sniffed from content, not extension: a dict with
``traceEvents`` is a trace, one with ``series`` is a metrics snapshot, a
JSONL file is a sink (its last line is summarized).
"""
from __future__ import annotations

import json
import os

__all__ = ["REQUIRED_SERVICE_SERIES", "load_any", "summarize_metrics",
           "summarize_trace", "check_metrics", "check_trace", "main"]

#: series a service-produced metrics snapshot must carry (the acceptance
#: bar of ISSUE 10): live paper observables + the coalescing health gauge.
REQUIRED_SERVICE_SERIES = (
    "repro_pass_u",
    "repro_pass_w2",
    "repro_pass_window_occupancy",
    "repro_service_coalescing_ratio",
)


def load_any(path) -> tuple[str, dict]:
    """Load a telemetry file, returning ``(kind, obj)``.

    ``kind`` is ``"trace"`` or ``"metrics"``.  JSONL sinks yield their
    last snapshot line.  A directory is resolved to its ``metrics.json``.
    Raises ValueError on unrecognized content.
    """
    if os.path.isdir(path):
        path = os.path.join(path, "metrics.json")
    with open(path) as fh:
        text = fh.read()
    lines = [ln for ln in text.splitlines() if ln.strip()]
    if not lines:
        raise ValueError(f"{path}: empty file")
    if len(lines) > 1 and not text.lstrip().startswith("{\n") \
            and all(ln.lstrip().startswith("{") for ln in lines):
        try:
            obj = json.loads(lines[-1])
        except json.JSONDecodeError:
            obj = json.loads(text)
    else:
        obj = json.loads(text)
    if not isinstance(obj, dict):
        raise ValueError(f"{path}: expected a JSON object")
    if "traceEvents" in obj:
        return "trace", obj
    if "series" in obj:
        return "metrics", obj
    raise ValueError(f"{path}: neither a trace (traceEvents) nor a "
                     f"metrics snapshot (series)")


def _fmt_labels(labels: dict) -> str:
    if not labels:
        return ""
    return "{" + ",".join(f"{k}={v}" for k, v in sorted(labels.items())) \
        + "}"


def summarize_metrics(snap: dict) -> str:
    """Text table of a metrics snapshot: one line per series."""
    rows = []
    for s in snap.get("series", []):
        name = s["name"] + _fmt_labels(s.get("labels", {}))
        unit = s.get("unit", "")
        if s.get("type") == "histogram":
            n = s.get("count", 0)
            mean = (s.get("sum", 0.0) / n) if n else float("nan")
            rows.append((name, s["type"],
                         f"count={n} mean={mean:.6g}", unit))
        else:
            rows.append((name, s.get("type", "?"),
                         f"{s.get('value', 0):.6g}", unit))
    if not rows:
        return "(no series)\n"
    w0 = max(len(r[0]) for r in rows)
    w1 = max(len(r[1]) for r in rows)
    out = [f"{n:<{w0}}  {t:<{w1}}  {v}" + (f" [{u}]" if u else "")
           for n, t, v, u in rows]
    return "\n".join(out) + "\n"


def summarize_trace(obj: dict) -> str:
    """Text table of a trace: per span name, count/total/mean duration."""
    agg: dict[str, list[float]] = {}
    for ev in obj.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        agg.setdefault(ev.get("name", "?"), []).append(
            float(ev.get("dur", 0.0)))
    if not agg:
        return "(no spans)\n"
    rows = []
    for name in sorted(agg):
        durs = agg[name]
        total = sum(durs)
        rows.append((name, len(durs), total / 1e3,
                     total / len(durs) / 1e3))
    w0 = max(len(r[0]) for r in rows)
    out = [f"{'span':<{w0}}  {'count':>5}  {'total_ms':>10}  {'mean_ms':>10}"]
    out += [f"{n:<{w0}}  {c:>5}  {t:>10.3f}  {m:>10.3f}"
            for n, c, t, m in rows]
    return "\n".join(out) + "\n"


def check_metrics(snap: dict) -> list[str]:
    """Validation problems of a metrics snapshot (empty list = OK)."""
    problems = []
    series = snap.get("series", [])
    if not series:
        problems.append("metrics snapshot has no series")
        return problems
    names = {s.get("name") for s in series}
    if any(isinstance(n, str) and n.startswith("repro_service_")
           for n in names):
        for req in REQUIRED_SERVICE_SERIES:
            match = [s for s in series if s.get("name") == req]
            if not match:
                problems.append(f"required service series missing: {req}")
            elif all(s.get("type") == "histogram" and
                     s.get("count", 0) < 1 for s in match):
                problems.append(f"required series never observed: {req}")
    for s in series:
        if s.get("type") == "histogram":
            counts, buckets = s.get("counts", []), s.get("buckets", [])
            if len(counts) != len(buckets) + 1:
                problems.append(
                    f"{s.get('name')}: {len(counts)} bucket counts for "
                    f"{len(buckets)} bounds (want bounds+1)")
            elif sum(counts) != s.get("count", -1):
                problems.append(
                    f"{s.get('name')}: bucket counts sum to "
                    f"{sum(counts)}, count says {s.get('count')}")
    return problems


def check_trace(obj: dict) -> list[str]:
    """Validation problems of a Chrome trace (empty list = OK).

    Spans must bracket properly inside each ``(pid, tid)`` lane: sorted by
    start (ties: longer first), every span must either nest inside the
    enclosing open span or start after it ends.  Partial overlap means the
    recorder's enter/exit discipline was violated.
    """
    problems = []
    events = obj.get("traceEvents", [])
    spans = [ev for ev in events if ev.get("ph") == "X"]
    if not spans:
        problems.append("trace has no complete ('X') spans")
        return problems
    for i, ev in enumerate(spans):
        for field in ("name", "ts", "dur", "pid", "tid"):
            if field not in ev:
                problems.append(f"span #{i} missing field {field!r}")
    if problems:
        return problems
    lanes: dict[tuple, list[dict]] = {}
    for ev in spans:
        lanes.setdefault((ev["pid"], ev["tid"]), []).append(ev)
    eps = 1e-6
    for lane, evs in sorted(lanes.items()):
        evs = sorted(evs, key=lambda e: (e["ts"], -e["dur"]))
        stack: list[dict] = []
        for ev in evs:
            t0, t1 = ev["ts"], ev["ts"] + ev["dur"]
            while stack and t0 >= stack[-1]["ts"] + stack[-1]["dur"] - eps:
                stack.pop()
            if stack and t1 > stack[-1]["ts"] + stack[-1]["dur"] + eps:
                outer = stack[-1]
                problems.append(
                    f"lane {lane}: span {ev['name']!r} "
                    f"[{t0}, {t1}] overlaps {outer['name']!r} "
                    f"[{outer['ts']}, {outer['ts'] + outer['dur']}] "
                    f"without nesting")
            stack.append(ev)
    return problems


def main(argv=None) -> int:
    """CLI entry point for ``python -m repro.obs summarize``."""
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro.obs",
        description="summarize/validate telemetry files "
                    "(metrics snapshots, JSONL sinks, Chrome traces)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sm = sub.add_parser("summarize",
                        help="render telemetry files as text tables")
    sm.add_argument("paths", nargs="+",
                    help="metrics.json / metrics dir / sink.jsonl / "
                         "trace.json")
    sm.add_argument("--check", action="store_true",
                    help="validate instead of merely rendering: non-empty,"
                         " required service series present, spans nest")
    args = ap.parse_args(argv)

    failures = 0
    for path in args.paths:
        try:
            kind, obj = load_any(path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            print(f"== {path}\nERROR: {e}")
            failures += 1
            continue
        print(f"== {path} ({kind})")
        print(summarize_metrics(obj) if kind == "metrics"
              else summarize_trace(obj), end="")
        if args.check:
            problems = (check_metrics(obj) if kind == "metrics"
                        else check_trace(obj))
            for p in problems:
                print(f"CHECK FAIL: {p}")
            failures += len(problems)
            if not problems:
                print("check ok")
    return 1 if failures else 0

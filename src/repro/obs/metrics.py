"""Metrics core: counter/gauge/histogram registry with labeled series.

Zero-dependency (stdlib only, no JAX, no numpy) so every layer — engine
drivers, sweep experiments, the service, the daemon, the benchmark harness
— can import it without touching the device runtime.  The instrumentation
contract of the whole ``repro.obs`` subsystem is **off-path observation**:
hooks only read host-side values that the instrumented code already
materialized (stats rows, scheduler state, wall clocks); they never issue
device work, so telemetry-on and telemetry-off runs are bit-identical
(asserted in tests/test_obs.py).

Three metric kinds, Prometheus-shaped:

* :class:`Counter` — monotonically non-decreasing totals.  ``inc`` adds;
  ``set_total`` mirrors an externally-accumulated cumulative counter
  (e.g. a ``ServiceStats`` field) into the registry.
* :class:`Gauge` — a value that can go both ways (queue depth, ratios).
* :class:`Histogram` — bucketed observations with ``sum``/``count``
  (per-pass observables, phase seconds).

Series are keyed by ``(metric name, sorted label items)``; a series exists
from its first update (never from mere instrument creation), so "series
present" in a snapshot means the instrumented path actually ran.

Exposition:

* :func:`MetricsRegistry.snapshot` — JSON-ready dict of every series;
* :func:`append_jsonl` — the JSONL metrics sink (one snapshot per line);
* :func:`to_prometheus` — Prometheus text exposition format;
* :func:`write_snapshot` — atomic ``metrics.json`` + ``metrics.prom`` pair
  in a directory, written with the same tmp+rename+fsync discipline as
  ``service.state_cache.StateCache.save`` (a reader never sees a torn
  file; the daemon calls this after every busy round).
"""
from __future__ import annotations

import json
import math
import os
import time

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_BUCKETS", "to_prometheus", "append_jsonl",
           "write_snapshot", "SNAPSHOT_BASENAME", "PROM_BASENAME"]

#: default histogram bucket upper bounds (seconds-flavored, Prometheus-ish);
#: instruments measuring ratios or physics quantities pass their own.
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0)

#: file names :func:`write_snapshot` maintains inside a ``--metrics-dir``.
SNAPSHOT_BASENAME = "metrics.json"
PROM_BASENAME = "metrics.prom"


def _label_key(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _fmt(v: float) -> str:
    """Prometheus float spelling: integral values bare, inf as +Inf."""
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


class _Metric:
    """Common shape of one named metric family (shared by all kinds)."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "", unit: str = ""):
        self.name = name
        self.help = help
        self.unit = unit
        self._series: dict[tuple, object] = {}

    @property
    def series(self) -> dict:
        """Live series, keyed by sorted ``(label, value)`` item tuples."""
        return self._series


class Counter(_Metric):
    """Monotonically non-decreasing total (per label set)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {amount})")
        k = _label_key(labels)
        self._series[k] = self._series.get(k, 0.0) + amount

    def set_total(self, value: float, **labels) -> None:
        """Mirror an externally-accumulated cumulative total.

        The service keeps its own ``ServiceStats`` ledger; telemetry syncs
        those fields here rather than double-counting.  Still monotone:
        lowering a total is a programming error and raises.
        """
        k = _label_key(labels)
        if value < self._series.get(k, 0.0):
            raise ValueError(
                f"counter {self.name}{dict(k)} cannot decrease "
                f"({self._series[k]} -> {value})")
        self._series[k] = float(value)

    def value(self, **labels) -> float:
        """Current total for the label set (0 if never updated)."""
        return float(self._series.get(_label_key(labels), 0.0))


class Gauge(_Metric):
    """Point-in-time value (queue depth, ratios, occupancy)."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self._series[_label_key(labels)] = float(value)

    def value(self, **labels) -> float:
        """Current value for the label set (0 if never set)."""
        return float(self._series.get(_label_key(labels), 0.0))


class Histogram(_Metric):
    """Bucketed observations with cumulative ``sum`` and ``count``."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", unit: str = "",
                 buckets=DEFAULT_BUCKETS):
        super().__init__(name, help, unit)
        b = tuple(sorted(float(x) for x in buckets))
        if not b:
            raise ValueError(f"histogram {name} needs at least one bucket")
        if any(x >= y for x, y in zip(b, b[1:])):
            raise ValueError(f"histogram {name} buckets must be strictly "
                             f"increasing: {buckets}")
        self.buckets = b

    def observe(self, value: float, **labels) -> None:
        k = _label_key(labels)
        s = self._series.get(k)
        if s is None:
            s = {"counts": [0] * (len(self.buckets) + 1),
                 "sum": 0.0, "count": 0}
            self._series[k] = s
        v = float(value)
        i = len(self.buckets)
        for j, ub in enumerate(self.buckets):
            if v <= ub:
                i = j
                break
        s["counts"][i] += 1
        s["sum"] += v
        s["count"] += 1

    def count(self, **labels) -> int:
        """Observations recorded for the label set (0 if none)."""
        s = self._series.get(_label_key(labels))
        return 0 if s is None else int(s["count"])


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class MetricsRegistry:
    """Get-or-create registry of named metrics, snapshot- and text-exposable.

    ``clock`` stamps snapshots (injectable for reproducible golden-file
    tests — the exposition tests fix it and re-render byte-identically).
    Re-requesting an existing name returns the same instrument; requesting
    it as a different kind raises, so two layers can't silently fork one
    series.
    """

    def __init__(self, clock=time.time):
        self._clock = clock
        self._metrics: dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, unit: str, **kw) -> _Metric:
        m = self._metrics.get(name)
        if m is not None:
            if type(m) is not cls:
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, requested {cls.kind}")
            return m
        m = cls(name, help=help, unit=unit, **kw)
        self._metrics[name] = m
        return m

    def counter(self, name: str, help: str = "", unit: str = "") -> Counter:
        """Get or create a :class:`Counter`."""
        return self._get(Counter, name, help, unit)

    def gauge(self, name: str, help: str = "", unit: str = "") -> Gauge:
        """Get or create a :class:`Gauge`."""
        return self._get(Gauge, name, help, unit)

    def histogram(self, name: str, help: str = "", unit: str = "",
                  buckets=DEFAULT_BUCKETS) -> Histogram:
        """Get or create a :class:`Histogram`."""
        return self._get(Histogram, name, help, unit, buckets=buckets)

    def __iter__(self):
        return iter(sorted(self._metrics.values(), key=lambda m: m.name))

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> dict:
        """JSON-ready dict of every live series (the sink/exposition unit).

        Shape::

            {"ts": <clock()>, "series": [
               {"name": ..., "type": "counter"|"gauge", "help": ...,
                "unit": ..., "labels": {...}, "value": ...},
               {"name": ..., "type": "histogram", ..., "labels": {...},
                "buckets": [...], "counts": [...], "sum": ..., "count": ...},
            ]}
        """
        series = []
        for m in self:
            for k in sorted(m.series):
                entry = {"name": m.name, "type": m.kind, "help": m.help,
                         "unit": m.unit, "labels": dict(k)}
                v = m.series[k]
                if m.kind == "histogram":
                    entry.update(buckets=list(m.buckets),
                                 counts=list(v["counts"]),
                                 sum=v["sum"], count=v["count"])
                else:
                    entry["value"] = v
                series.append(entry)
        return {"ts": float(self._clock()), "series": series}


def to_prometheus(registry: MetricsRegistry) -> str:
    """Render a registry in the Prometheus text exposition format.

    Deterministic: metrics sorted by name, series by label key, floats in
    the canonical spelling of :func:`_fmt` — re-rendering an unchanged
    registry is byte-identical (golden-filed in tests/test_obs.py).
    """
    def esc(v: str) -> str:
        return v.replace("\\", "\\\\").replace('"', '\\"').replace(
            "\n", "\\n")

    def lbl(k: tuple, extra: tuple = ()) -> str:
        items = list(k) + list(extra)
        if not items:
            return ""
        return "{" + ",".join(f'{name}="{esc(val)}"'
                              for name, val in items) + "}"

    lines = []
    for m in registry:
        if not m.series:
            continue
        if m.help:
            lines.append(f"# HELP {m.name} {esc(m.help)}")
        lines.append(f"# TYPE {m.name} {m.kind}")
        for k in sorted(m.series):
            v = m.series[k]
            if m.kind == "histogram":
                acc = 0
                for ub, c in zip((*m.buckets, math.inf), v["counts"]):
                    acc += c
                    lines.append(f"{m.name}_bucket"
                                 f"{lbl(k, (('le', _fmt(ub)),))} {acc}")
                lines.append(f"{m.name}_sum{lbl(k)} {_fmt(v['sum'])}")
                lines.append(f"{m.name}_count{lbl(k)} {v['count']}")
            else:
                lines.append(f"{m.name}{lbl(k)} {_fmt(v)}")
    return "\n".join(lines) + ("\n" if lines else "")


def append_jsonl(registry: MetricsRegistry, path) -> dict:
    """Append one snapshot line to a JSONL metrics sink; returns it.

    The flat-file cousin of a scrape: every call adds a timestamped
    snapshot, so per-round rates fall out of adjacent-line differences
    (``python -m repro.obs summarize`` reads the last line).
    """
    snap = registry.snapshot()
    with open(path, "a") as fh:
        fh.write(json.dumps(snap) + "\n")
        fh.flush()
    return snap


def write_snapshot(registry: MetricsRegistry, directory) -> dict:
    """Atomically write ``metrics.json`` + ``metrics.prom`` into a directory.

    The daemon's ``--metrics-dir`` exposition: after each busy round the
    registry is rendered to both formats and each file is replaced via
    write-to-``.tmp`` + fsync + rename — the same discipline as
    ``StateCache.save`` — so a concurrent reader (scrape cron, tail -f
    dashboard) never observes a torn snapshot.  Returns the snapshot dict.
    """
    os.makedirs(directory, exist_ok=True)
    snap = registry.snapshot()
    for base, text in ((SNAPSHOT_BASENAME, json.dumps(snap, indent=1)),
                       (PROM_BASENAME, to_prometheus(registry))):
        path = os.path.join(directory, base)
        tmp = f"{path}.tmp"
        with open(tmp, "w") as fh:
            fh.write(text)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
    return snap

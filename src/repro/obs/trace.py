"""Span tracing: Chrome-trace/Perfetto JSON emission with an ambient tracer.

A :class:`TraceRecorder` collects completed spans as Chrome trace events
(``ph: "X"`` — complete events with microsecond ``ts``/``dur``) that load
directly into ``chrome://tracing`` / Perfetto.  The clock and pid are
injectable so golden-file tests can produce byte-stable traces; production
callers take the defaults (``time.perf_counter``, real pid).

Instrumented library code does not thread a recorder through every call —
it asks for the process-ambient tracer::

    from repro.obs import trace

    with trace.span("burn", args={"n_burn": n_burn}):
        state = eng.burn_in(state, n_burn)

When no tracer is installed (:func:`set_tracer` never called, or called
with ``None``) the :func:`span` helper is a no-op costing one dict lookup,
so the hot path stays clean for ordinary library users.  The harnesses
that want a trace (``benchmarks/run.py --trace``, the service daemon,
``python -m repro.service --trace``) install a recorder around their run
and :meth:`TraceRecorder.save` it at exit.

Spans are strictly nested per thread (enter/exit discipline of ``with``),
which is exactly what ``repro.obs.summarize --check`` verifies on the
emitted file.  Timing spans around asynchronously-dispatched JAX work
should only block on the result when a tracer is live — see
``experiments.sweep.run_window_sweep`` — keeping telemetry-off runs
dispatch-identical to uninstrumented code.
"""
from __future__ import annotations

import json
import os
import threading
import time

__all__ = ["TraceRecorder", "Span", "set_tracer", "current_tracer", "span"]


class Span:
    """One in-flight span; mutate ``args`` to annotate before exit."""

    __slots__ = ("name", "cat", "args", "_t0", "_tid")

    def __init__(self, name: str, cat: str, args: dict):
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0
        self._tid = 0


class TraceRecorder:
    """Collects spans and serializes them as Chrome trace JSON.

    ``clock`` must be a monotonic seconds source (default
    ``time.perf_counter``); timestamps in the output are microseconds
    relative to the recorder's construction.  ``pid`` defaults to the real
    process id and is injectable for reproducible goldens.  Thread-safe:
    each thread gets its own ``tid`` and its own nesting stack.
    """

    def __init__(self, clock=time.perf_counter, pid: int | None = None):
        self._clock = clock
        self._pid = os.getpid() if pid is None else int(pid)
        self._t0 = clock()
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._tids: dict[int, int] = {}
        self._local = threading.local()

    def _tid(self) -> int:
        ident = threading.get_ident()
        with self._lock:
            tid = self._tids.get(ident)
            if tid is None:
                tid = len(self._tids) + 1
                self._tids[ident] = tid
            return tid

    def _us(self, t: float) -> float:
        return round((t - self._t0) * 1e6, 3)

    def span(self, name: str, cat: str = "repro", args: dict | None = None):
        """Context manager recording one complete event around its body.

        Yields the :class:`Span` so the body can add ``args`` entries that
        are only known mid-flight (row counts, cache provenance).  On an
        exception the span still closes, with ``args["error"]`` set to the
        exception type name, and the exception propagates.
        """
        return _SpanCtx(self, Span(name, cat, dict(args or {})))

    def _open(self, s: Span) -> None:
        s._t0 = self._clock()
        s._tid = self._tid()
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        stack.append(s)

    def _close(self, s: Span, exc: BaseException | None) -> None:
        t1 = self._clock()
        stack = getattr(self._local, "stack", [])
        if stack and stack[-1] is s:
            stack.pop()
        if exc is not None:
            s.args.setdefault("error", type(exc).__name__)
        ev = {"name": s.name, "cat": s.cat, "ph": "X",
              "ts": self._us(s._t0), "dur": round((t1 - s._t0) * 1e6, 3),
              "pid": self._pid, "tid": s._tid}
        if s.args:
            ev["args"] = s.args
        with self._lock:
            self._events.append(ev)

    @property
    def events(self) -> list[dict]:
        """Completed events, in completion order."""
        with self._lock:
            return list(self._events)

    def __len__(self) -> int:
        with self._lock:
            return len(self._events)

    def to_dict(self) -> dict:
        """Chrome trace object: ``{"traceEvents": [...], ...}``."""
        return {"traceEvents": self.events, "displayTimeUnit": "ms"}

    def save(self, path) -> None:
        """Atomically write the trace JSON (tmp+rename, fsync'd)."""
        tmp = f"{path}.tmp"
        with open(tmp, "w") as fh:
            json.dump(self.to_dict(), fh, indent=1)
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)


class _SpanCtx:
    __slots__ = ("_rec", "_span")

    def __init__(self, rec: TraceRecorder, s: Span):
        self._rec = rec
        self._span = s

    def __enter__(self) -> Span:
        self._rec._open(self._span)
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        self._rec._close(self._span, exc)
        return False


class _NullSpanCtx:
    """No-tracer fallback: yields None, records nothing."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False


_NULL = _NullSpanCtx()
_ambient: TraceRecorder | None = None


def set_tracer(tracer: TraceRecorder | None) -> TraceRecorder | None:
    """Install the process-ambient tracer; returns the previous one.

    Harness-level API: the benchmark runner and the service CLI install a
    recorder around their run and restore the previous value after, so a
    library call tree needs no tracer plumbing.
    """
    global _ambient
    prev = _ambient
    _ambient = tracer
    return prev


def current_tracer() -> TraceRecorder | None:
    """The installed ambient tracer, or None."""
    return _ambient


def span(name: str, cat: str = "repro", args: dict | None = None):
    """Span against the ambient tracer; no-op (yields None) if none set.

    Instrumentation sites use the yielded value's truthiness to decide
    whether trace-only work (e.g. ``jax.block_until_ready`` for honest
    phase attribution) should run at all.
    """
    t = _ambient
    if t is None:
        return _NULL
    return t.span(name, cat=cat, args=args)

"""``repro.obs`` — zero-dependency telemetry: metrics, tracing, exposition.

The observability layer of the reproduction (see the "Observability"
section of docs/architecture.md).  Three pieces:

* :mod:`repro.obs.metrics` — counter/gauge/histogram registry with
  labeled series, JSONL sink, Prometheus text exposition, atomic
  snapshot writer;
* :mod:`repro.obs.trace` — span API emitting Chrome-trace/Perfetto JSON,
  with a process-ambient tracer so library code needs no plumbing;
* :mod:`repro.obs.summarize` — ``python -m repro.obs summarize
  [--check]`` renders/validates the emitted files (used by CI).

:class:`Telemetry` bundles a registry with an optional tracer — the
single handle the service, daemon, and CLIs pass around.  Everything here
is stdlib-only and strictly off-path: instrumentation observes host-side
values the instrumented code already materialized, never issues device
work, and telemetry-on runs are bit-identical to telemetry-off runs
(tests/test_obs.py).
"""
from __future__ import annotations

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      append_jsonl, to_prometheus, write_snapshot)
from .trace import TraceRecorder, current_tracer, set_tracer, span

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "append_jsonl", "to_prometheus", "write_snapshot",
           "TraceRecorder", "current_tracer", "set_tracer", "span",
           "Telemetry"]


class Telemetry:
    """A metrics registry plus an optional trace recorder, as one handle.

    ``Telemetry()`` gives live metrics only; pass ``tracer=`` to also
    record spans.  ``spans()`` proxies to the tracer when present and is
    a no-op context manager otherwise, so instrumented code never
    branches on tracer presence.
    """

    def __init__(self, registry: MetricsRegistry | None = None,
                 tracer: TraceRecorder | None = None):
        self.registry = registry if registry is not None \
            else MetricsRegistry()
        self.tracer = tracer

    def spans(self, name: str, cat: str = "repro",
              args: dict | None = None):
        """Span on this bundle's tracer; inert if no tracer attached."""
        from .trace import _NULL
        if self.tracer is None:
            return _NULL
        return self.tracer.span(name, cat=cat, args=args)

"""nondeterministic-reduction: no order-sensitive collective on the tau path.

The repo claims *bit-identical* trajectories across backends (the parity
tests depend on it).  A floating-point ``psum`` / all-reduce-add has
unspecified reduction order across replicas, so its result may differ
between topologies — harmless for *statistics* (parity is claimed for
trajectories, and the stats all-reduce in ``_finish_chunk`` is explicitly
exempt), fatal if it feeds the trajectory itself (e.g. deriving a window
base from a mean).  ``pmin``/``pmax`` are order-insensitive and always
allowed; integer sums are exact and allowed too.
"""
from __future__ import annotations

import numpy as np

from ..probes import Probe
from ..report import Finding
from .common import tau_io, where

RULE = "nondeterministic-reduction"

_ORDER_SENSITIVE = ("psum", "psum2", "all_reduce_sum")


def check(probe: Probe, **_) -> list:
    graph = probe.graph
    _, tau_out = tau_io(graph, probe)
    anc = graph.ancestors(tau_out)
    findings = []
    for n in graph.nodes:
        if n.prim not in _ORDER_SENSITIVE:
            continue
        if not np.issubdtype(getattr(n.aval, "dtype", np.int32),
                             np.floating):
            continue                   # integer sums are exact
        if n.gid not in anc:
            continue                   # stats-only reduction: exempt
        findings.append(Finding(
            rule=RULE, op=n.prim, path=where(n),
            message="order-unspecified floating-point cross-replica sum on "
                    "the tau dataflow path; bit-identical trajectory parity "
                    "cannot hold (use pmin/pmax or integer sums)"))
    return findings

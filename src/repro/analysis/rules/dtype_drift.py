"""dtype-drift: no silent f32->f64 / i32->i64 promotion on any backend path.

Cross-backend bit-parity (the repo's core testing strategy) only holds if
every backend computes in exactly the declared dtypes: a stray Python float
captured as f64, or an unannotated ``arange``, changes rounding and breaks
trajectory equality between ``horizon.conservative_update`` and the kernels.

Probes are traced under ``enable_x64`` (see probes.py), so with 64-bit types
*available*, any promotion materializes as a 64-bit aval in the graph.  The
rule scans every node for 64-bit results (the clean tree is dtype-
disciplined and has none) and additionally pins the tau output to the
declared base dtype.
"""
from __future__ import annotations

from ..probes import Probe
from ..report import Finding
from .common import tau_io, where

RULE = "dtype-drift"

_WIDE = ("float64", "int64", "uint64", "complex128")


def check(probe: Probe, **_) -> list:
    graph = probe.graph
    findings = []
    seen = set()
    for n in graph.nodes:
        dt = str(getattr(n.aval, "dtype", ""))
        if dt not in _WIDE or n.prim in ("input", "const"):
            continue
        first_drift = all(
            str(getattr(graph.node(d).aval, "dtype", "")) not in _WIDE
            for d in n.deps)
        if not first_drift:
            continue                   # report the promotion site, not users
        key = (n.prim, n.src, n.path)
        if key in seen:
            continue
        seen.add(key)
        findings.append(Finding(
            rule=RULE, op=n.prim, path=where(n),
            message=f"silent promotion to {dt} (declared base dtype "
                    f"{probe.dtype}); 64-bit intermediates break "
                    "cross-backend bit parity"))
    _, tau_out = tau_io(graph, probe)
    out_dt = str(getattr(graph.node(tau_out).aval, "dtype", ""))
    if out_dt and out_dt != probe.dtype:
        findings.append(Finding(
            rule=RULE, op=graph.node(tau_out).prim,
            path=where(graph.node(tau_out)),
            message=f"tau output dtype {out_dt} != declared {probe.dtype}"))
    return findings

"""tau-monotonicity: no dataflow path may decrease a local virtual time.

Conservative PDES correctness requires every PE's local virtual time to be
non-decreasing: a tau write must be the old value plus a provably
non-negative increment (or a guarded select between such values).  The rule
combines two analyses over the flattened graph:

* **interval analysis** — forward value ranges seeded from dtype bounds
  (every uint32 is clamped to ``[0, 2^32-1]`` after each op, so wrap-around
  hashes stay bounded).  This is what proves the exponential increment
  ``eta = -log(u + 2^-25)`` is structurally positive: the top-24-bit decode
  bounds ``u + 2^-25`` inside ``(0, 1)``, so ``-log`` of it is ``> 0``.
* **monotone walk** — a memoized structural recursion from the tau output:
  the old tau value may flow through views, concats (rolls/halos select tau
  *values*, they never scale them), carries, and selects; it may be combined
  only via ``add`` with an interval-non-negative term, ``max``, or — the one
  sanctioned decrease — subtraction of the *ring-uniform* rebase shift
  (a ``reduce_min``/``pmin`` over the whole ring: subtracting the global
  minimum shifts all clocks equally and preserves relative causality).

Any other path (e.g. the seeded ``eta - 1.0`` fixture) fails with the
offending node as witness.
"""
from __future__ import annotations

import math

import numpy as np

from ..graph import ring_axis_of
from ..probes import Probe
from ..report import Finding
from .common import (PASSTHROUGH, const_bounds, ring_min_gids, tau_io, where)

RULE = "tau-monotonicity"

_UNK = (-math.inf, math.inf)

_DTYPE_RANGE = {
    "uint8": (0, 2**8 - 1), "uint16": (0, 2**16 - 1),
    "uint32": (0, 2**32 - 1), "uint64": (0, 2**64 - 1),
    "int8": (-2**7, 2**7 - 1), "int16": (-2**15, 2**15 - 1),
    "int32": (-2**31, 2**31 - 1), "int64": (-2**63, 2**63 - 1),
    "bool": (0, 1),
}


def _clamp(iv, aval):
    dt = str(getattr(aval, "dtype", ""))
    rng = _DTYPE_RANGE.get(dt)
    if rng is None:
        return iv
    return (max(iv[0], rng[0]), min(iv[1], rng[1]))


def _dtype_range(aval):
    return _DTYPE_RANGE.get(str(getattr(aval, "dtype", "")), _UNK)


def _mul(a, b):
    cands = [a[0] * b[0], a[0] * b[1], a[1] * b[0], a[1] * b[1]]
    cands = [c for c in cands if not math.isnan(c)]
    return (min(cands), max(cands)) if cands else _UNK


def _log(iv):
    lo = math.log(iv[0]) if iv[0] > 0 else -math.inf
    hi = math.log(iv[1]) if iv[1] > 0 else -math.inf
    return (lo, hi)


def compute_intervals(graph) -> dict:
    """Forward value ranges per gid (dtype-clamped after every transfer)."""
    iv: dict[int, tuple] = {}
    for n in graph.nodes:
        d = [iv.get(g, _UNK) for g in n.deps]
        r = _UNK
        p = n.prim
        if p == "const":
            r = const_bounds(n.params.get("val")) or _UNK
        elif p == "input":
            r = _dtype_range(n.aval)
        elif p == "iota":
            shape = getattr(n.aval, "shape", None) or (1,)
            r = (0, max(shape) - 1)
        elif p in PASSTHROUGH or p in ("scan_xs", "scan_stack", "slice",
                                       "concatenate", "reduce_min",
                                       "reduce_max", "pmin", "pmax",
                                       "ppermute"):
            r = (min((x[0] for x in d), default=-math.inf),
                 max((x[1] for x in d), default=math.inf)) if d else _UNK
        elif p == "add":
            r = (d[0][0] + d[1][0], d[0][1] + d[1][1])
        elif p == "sub":
            r = (d[0][0] - d[1][1], d[0][1] - d[1][0])
        elif p == "mul":
            r = _mul(d[0], d[1])
        elif p == "neg":
            r = (-d[0][1], -d[0][0])
        elif p == "abs":
            lo = 0.0 if d[0][0] <= 0 <= d[0][1] else min(abs(d[0][0]),
                                                         abs(d[0][1]))
            r = (lo, max(abs(d[0][0]), abs(d[0][1])))
        elif p == "exp":
            r = (math.exp(min(d[0][0], 700)), math.exp(min(d[0][1], 700)))
        elif p == "log":
            r = _log(d[0])
        elif p == "sqrt":
            r = (math.sqrt(max(d[0][0], 0)),
                 math.sqrt(max(d[0][1], 0)) if d[0][1] >= 0 else 0.0)
        elif p == "max":
            r = (max(d[0][0], d[1][0]), max(d[0][1], d[1][1]))
        elif p == "min":
            r = (min(d[0][0], d[1][0]), min(d[0][1], d[1][1]))
        elif p == "shift_right_logical":
            if d[1][0] == d[1][1] and float(d[1][0]).is_integer() \
                    and d[0][0] >= 0:
                s = int(d[1][0])
                r = (int(d[0][0]) >> s,
                     int(min(d[0][1], 2**64)) >> s)
        elif p in ("rem", "remainder"):
            if d[1][0] > 0:
                r = (0 if d[0][0] >= 0 else -d[1][1] + 1, d[1][1] - 1)
        elif p in ("select_n", "cond_join"):
            cases = d[1:] if len(d) > 1 else d
            r = (min(x[0] for x in cases), max(x[1] for x in cases))
        elif p in ("eq", "ne", "lt", "le", "gt", "ge", "and", "or", "not",
                   "xor", "is_finite", "reduce_and", "reduce_or"):
            r = (0, 1)
        elif p == "reduce_sum":
            if d and d[0][0] >= 0:
                r = (0, math.inf)
        elif p == "psum":
            if d and d[0][0] >= 0:
                r = (0, math.inf)
        elif p == "convert_element_type":
            r = d[0] if d else _UNK
        iv[n.gid] = _clamp(r, n.aval)
    return iv


#: prims through which "is (a view of) the old tau value" propagates
_MONO_VIEWS = PASSTHROUGH | {"slice", "concatenate", "scan_carry",
                             "scan_stack", "ppermute", "cond_join"}


def check(probe: Probe, **_) -> list:
    graph = probe.graph
    iv = compute_intervals(graph)
    window = ring_min_gids(graph, probe)
    tau_in, tau_out = tau_io(graph, probe)
    memo: dict[int, tuple] = {}

    def uniform_shift(gid) -> bool:
        """Ring-uniform rebase amount: derives from a full-ring min."""
        anc = graph.ancestors(gid)
        return bool(anc & window)

    def mono(gid):
        """(ok, witness_gid): is node a non-decreasing function of tau?"""
        if gid in memo:
            return memo[gid]
        memo[gid] = (True, None)       # cycle guard (carries)
        n = graph.node(gid)
        res = (False, gid)
        if gid == tau_in or n.prim == "ref_carry":
            res = (True, None)
        elif n.prim == "scan_carry":
            res = mono(n.deps[0]) if n.deps else (True, None)
        elif n.prim in ("pallas_out", "ref_swap"):
            res = mono(n.deps[0])      # dep[1:] are provenance/index only
        elif n.prim in _MONO_VIEWS:
            res = (True, None)
            for i, d in enumerate(n.deps):
                if n.prim == "cond_join" and i == 0:
                    continue           # the predicate does not carry values
                ok, w = mono(d)
                if not ok:
                    res = (False, w)
                    break
        elif n.prim == "select_n":
            res = (True, None)
            for d in n.deps[1:]:
                ok, w = mono(d)
                if not ok:
                    res = (False, w)
                    break
        elif n.prim == "add":
            for i, j in ((0, 1), (1, 0)):
                ok, _w = mono(n.deps[i])
                if ok and iv.get(n.deps[j], _UNK)[0] >= 0:
                    res = (True, None)
                    break
            else:
                res = (False, gid)
        elif n.prim == "max":
            oks = [mono(d) for d in n.deps]
            res = (True, None) if any(ok for ok, _ in oks) else (False, gid)
        elif n.prim == "sub":
            ok, _w = mono(n.deps[0])
            if ok and uniform_shift(n.deps[1]):
                res = (True, None)      # the sanctioned GVT rebase
            else:
                res = (False, gid)
        memo[gid] = res
        return res

    findings = []

    def verify(gid, what):
        ok, witness = mono(gid)
        if ok:
            return
        n = graph.node(witness if witness is not None else gid)
        lohi = iv.get(n.gid)
        extra = ""
        if n.prim == "add" and len(n.deps) == 2:
            incs = [iv.get(d, _UNK) for d in n.deps]
            lo = min(x[0] for x in incs)
            extra = f" (increment may be as low as {lo:.3g})"
        elif lohi and lohi[0] < 0:
            extra = f" (value range [{lohi[0]:.3g}, {lohi[1]:.3g}])"
        findings.append(Finding(
            rule=RULE, op=n.prim, path=where(n),
            message=f"{what} is not a provably non-decreasing update of "
                    f"tau{extra}"))

    verify(tau_out, "tau output")
    seen = {tau_out}
    for n in graph.nodes:
        # only ring-shaped tau carries: stats/offset accumulators are not
        # virtual times and have no monotonicity obligation
        if n.prim not in ("scan_carry", "ref_carry") or \
                "carry_out" not in n.params:
            continue
        if ring_axis_of(n.aval, probe.ring_widths) is None:
            continue
        if not np.issubdtype(getattr(n.aval, "dtype", np.int32), np.floating):
            continue
        if n.deps and tau_in not in graph.ancestors(n.deps[0]):
            continue                   # loop does not carry tau at all
        co = n.params["carry_out"]
        if co not in seen:
            seen.add(co)
            verify(co, "loop-carried tau")
    return findings

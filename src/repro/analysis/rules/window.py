"""window-bound guard: every advance must be dominated by a comparison
against the window base when the window is finite.

The moving-window rule (paper Eq. (3), ``tau_k <= delta + GVT``) is what
bounds memory and guarantees measurement-phase scalability; a backend that
silently drops the comparison still produces plausible trajectories.  The
rule finds every *advance site* — a ``select_n`` of tau's dtype on the tau
output's dataflow (the ``where(update, eta, 0)`` increments) — and requires
its predicate's ancestry to contain a comparison fed by the window base:
a full-ring min reduction (``reduce_min`` / ``pmin`` over the ring), or,
for sweep probes, the per-row ``deltas=`` operand column (which must reach
*every* site's predicate — a sweep that ignores its Δ column for some rows
is a silent correctness bug).
"""
from __future__ import annotations

import math

import numpy as np

from ..probes import Probe
from ..report import Finding
from .common import ring_min_gids, tau_io, where

RULE = "window-bound"

_COMPARES = ("le", "lt", "ge", "gt")


def _advance_sites(graph, tau_out):
    """select_n nodes of float dtype on the tau output's ancestry."""
    anc = graph.ancestors(tau_out)
    sites = []
    for n in graph.nodes:
        if n.gid not in anc or n.prim != "select_n" or len(n.deps) < 2:
            continue
        if np.issubdtype(getattr(n.aval, "dtype", np.int32), np.floating):
            sites.append(n)
    return sites


def check(probe: Probe, **_) -> list:
    finite = probe.delta is not None and math.isfinite(probe.delta)
    if not finite and probe.delta_input is None:
        return []                       # unconstrained run: nothing to guard
    graph = probe.graph
    _, tau_out = tau_io(graph, probe)
    window = ring_min_gids(graph, probe)
    delta_gid = (graph.in_gids[probe.delta_input]
                 if probe.delta_input is not None else None)
    findings = []
    sites = _advance_sites(graph, tau_out)
    if not sites:
        findings.append(Finding(
            rule=RULE,
            message="no guarded advance site found on the tau output path "
                    "(expected a select over the update predicate)"))
        return findings
    for s in sites:
        pred_anc = graph.ancestors(s.deps[0])
        compares = [g for g in pred_anc
                    if graph.node(g).prim in _COMPARES]
        guarded = False
        sweep_guarded = delta_gid is None
        for c in compares:
            c_anc = graph.ancestors(c)
            if c_anc & window:
                guarded = True
            if delta_gid is not None and delta_gid in c_anc:
                sweep_guarded = True
        if not guarded:
            findings.append(Finding(
                rule=RULE, op=s.prim, path=where(s),
                message="advance is not dominated by a comparison against "
                        "the window base (no full-ring min reaches the "
                        "update predicate)"))
        elif not sweep_guarded:
            findings.append(Finding(
                rule=RULE, op=s.prim, path=where(s),
                message="sweep advance ignores the per-row deltas= operand: "
                        "the window comparison never reads the Δ column"))
    return findings

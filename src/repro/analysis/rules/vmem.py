"""vmem-budget: per-BlockSpec VMEM footprint of every Pallas kernel call.

Each program instance of ``pdes_step`` / ``pdes_multistep`` /
``pdes_multistep_counter`` owns one VMEM tile per operand/output BlockSpec.
The footprint is fully static — block shapes x dtypes off the
``grid_mapping`` the call was traced with — so exceeding the budget is a
compile-time fact, not a runtime surprise.  The default budget (16 MiB)
matches a TPU core's VMEM; tune with ``--vmem-budget`` (the engine's own
auto-tiler targets 8 MiB, leaving headroom for double buffering).
"""
from __future__ import annotations

import numpy as np

from ..probes import Probe
from ..report import Finding
from .common import where

RULE = "vmem-budget"

DEFAULT_BUDGET = 16 << 20          # bytes; one TPU core's VMEM


def _block_bytes(bm) -> int:
    shape = getattr(bm, "block_shape", None)
    if shape is None:
        return 0
    n = 1
    for d in shape:
        n *= int(d) if isinstance(d, (int, np.integer)) else 1
    asd = getattr(bm, "array_shape_dtype", None)
    itemsize = np.dtype(getattr(asd, "dtype", np.float32)).itemsize
    return n * itemsize


def check(probe: Probe, vmem_budget: int = DEFAULT_BUDGET, **_) -> list:
    findings = []
    for n in probe.graph.find("pallas_call"):
        gm = n.params.get("grid_mapping")
        mappings = getattr(gm, "block_mappings", None)
        if not mappings:
            continue
        per_block = [_block_bytes(bm) for bm in mappings]
        total = sum(per_block)
        if total > vmem_budget:
            kname = n.params.get("name") or "pallas_call"
            biggest = max(per_block)
            findings.append(Finding(
                rule=RULE, op=kname, path=where(n),
                message=f"kernel tiles need {total / 2**20:.1f} MiB VMEM "
                        f"(largest block {biggest / 2**20:.1f} MiB) > "
                        f"budget {vmem_budget / 2**20:.1f} MiB across "
                        f"{len(per_block)} BlockSpecs"))
    return findings

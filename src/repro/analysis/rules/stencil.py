"""stencil-locality: tau updates may reach only {i-1, i, i+1} ring neighbors.

Toroczkai et al. show the horizon statistics are set by the communication
stencil itself, so a leaked next-nearest-neighbor dependence is a correctness
bug even when short parity tests pass.  This rule proves the nearest-neighbor
property by abstract interpretation of the flattened jaxpr.

Abstraction ("ring reach"): every value is either

* ``None`` — no per-site dependence on tau (event bits, iotas, constants,
  and full-ring reductions: the GVT/window channel is *uniform* across the
  ring and is the paper's sanctioned global constraint, so it does not count
  toward the stencil); or
* ``(lo, hi)`` — output position ``p`` depends only on tau sites
  ``[p + lo, p + hi]`` (ring coordinates, global across shards); or
* ``TOP`` — an un-analyzable ring-indexed op was hit (conservative fail).

Transfer highlights:

* ``slice`` by start ``s`` on the ring axis shifts reach by ``+s``;
  ``concatenate`` shifts each piece by ``-offset`` and takes the hull after
  normalizing each contribution mod the true ring size ``L`` — this makes
  circular constructs *exact*: ``jnp.roll(tau, 1)`` (slice+concat) and the
  wrap-halo ``concat([tau[:,-1:], tau, tau[:,:1]])`` both come out as the
  degenerate reach ``(-1, -1)``.
* the clamp-pad ``concat([x[:,:1], x, x[:,-1:]])`` of the communication-
  avoiding mode is recognized structurally and treated as alignment-shifting
  only (the duplicated edge values lie within the strip's existing reach).
* ``ppermute`` by a uniform shard shift ``s`` moves reach by ``-s * L_local``
  (so a distance-2 permute shows up as a reach of ``2 * L_local``).
* ``scan`` / revisited pallas tiles: the body is inlined once, so the check
  is *per step*: for every ring-shaped carry, ``reach(out) - reach(in)``
  must lie within ``[-1, +1]``, and the probe's tau output must end within
  ``[-1, +1]`` of its carry basis.

For sharded probes the lowered HLO is additionally checked: every
``collective-permute``'s ``source_target_pairs`` must be a ±1 neighbor shift
within each ring replica group.
"""
from __future__ import annotations

from ..graph import Graph, ring_axis_of
from ..probes import Probe
from ..report import Finding
from .common import (ELEMENTWISE, NAMED_REDUCE, PASSTHROUGH, RING_REDUCE,
                     is_ring_reduction, named_axes, tau_io, where)

RULE = "stencil-locality"
TOP = "TOP"


def _hull(reaches):
    acc = None
    for r in reaches:
        if r is None:
            continue
        if r == TOP or acc == TOP:
            return TOP
        acc = r if acc is None else (min(acc[0], r[0]), max(acc[1], r[1]))
    return acc


def _shift(r, s):
    if r is None or r == TOP:
        return r
    return (r[0] + s, r[1] + s)


def _norm(r, L):
    """Normalize a reach interval mod the ring size (midpoint near 0)."""
    if r is None or r == TOP or L <= 0:
        return r
    k = round(((r[0] + r[1]) / 2) / L)
    return (r[0] - k * L, r[1] - k * L)


def _is_clamp_pad(graph, node):
    """concat([x[:, :1], x, x[:, -1:]], axis) -> gid of x, else None."""
    if len(node.deps) != 3:
        return None
    a, x, b = (graph.node(d) for d in node.deps)
    dim = node.params.get("dimension")
    for edge, start_at_end in ((a, False), (b, True)):
        if edge.prim != "slice" or not edge.deps or edge.deps[0] != x.gid:
            return None
        xs = x.aval.shape
        starts = edge.params.get("start_indices", ())
        limits = edge.params.get("limit_indices", ())
        if dim is None or dim >= len(starts):
            return None
        want = (xs[dim] - 1, xs[dim]) if start_at_end else (0, 1)
        if (starts[dim], limits[dim]) != want:
            return None
    return x.gid


def _ppermute_shift(node):
    """Uniform shard shift of a ppermute perm, else None."""
    perm = node.params.get("perm")
    if not perm:
        return None
    n = len(perm)
    shifts = {(t - s) % n for s, t in perm}
    if len(shifts) != 1:
        return None
    s = shifts.pop()
    return s - n if s > n // 2 else s


def _compute_reach(graph: Graph, probe: Probe):
    tau_in, _ = tau_io(graph, probe)
    L = probe.L_ring
    reach: dict[int, object] = {}
    top_origin: dict[int, int] = {}   # gid -> gid of first-TOP ancestor

    def mark_top(n, deps_r):
        for d, r in zip(n.deps, deps_r):
            if r == TOP:
                return top_origin.get(d, d)
        return n.gid

    for n in graph.nodes:
        deps_r = [reach.get(d) for d in n.deps]
        r = None
        if n.prim == "input":
            r = (0, 0) if n.gid == tau_in else None
        elif n.prim in ("const", "iota", "pallas_call"):
            r = None
        elif n.prim in ("scan_carry",):
            r = deps_r[0] if deps_r else None
        elif n.prim == "ref_carry":
            ring_shaped = ring_axis_of(n.aval, probe.ring_widths) is not None
            r = (0, 0) if ring_shaped else None
        elif n.prim == "ppermute":
            if deps_r and deps_r[0] is not None:
                s = _ppermute_shift(n)
                L_l = None
                for a in named_axes(n):
                    L_l = probe.shard_L.get(a, L_l)
                if s is None or L_l is None:
                    r = TOP
                else:
                    r = _shift(deps_r[0], -s * L_l)
            else:
                r = None
        elif n.prim in RING_REDUCE or n.prim in NAMED_REDUCE:
            if is_ring_reduction(graph, n, probe):
                r = None              # the sanctioned global (window) channel
            else:
                r = _hull(deps_r)
        elif n.prim == "slice":
            dr = deps_r[0] if deps_r else None
            if dr in (None, TOP):
                r = dr
            else:
                dep = graph.node(n.deps[0])
                rax = ring_axis_of(dep.aval, probe.ring_widths)
                if rax is None:
                    r = dr             # slicing non-ring axes only
                else:
                    starts = n.params.get("start_indices", ())
                    strides = n.params.get("strides") or (1,) * len(starts)
                    r = TOP if strides[rax] != 1 else _shift(dr, starts[rax])
        elif n.prim == "concatenate":
            if all(dr is None for dr in deps_r):
                r = None
            else:
                dim = n.params.get("dimension")
                rax = ring_axis_of(n.aval, probe.ring_widths)
                dep0 = graph.node(n.deps[0])
                dax = ring_axis_of(dep0.aval, probe.ring_widths)
                if dim != rax and dim != dax:
                    r = _hull(deps_r)  # stacking along a non-ring axis
                else:
                    pad_of = _is_clamp_pad(graph, n)
                    if pad_of is not None:
                        r = _shift(reach.get(pad_of), -1)
                    else:
                        off, parts = 0, []
                        for d, dr in zip(n.deps, deps_r):
                            w = graph.node(d).aval.shape[dim]
                            if dr is not None:
                                parts.append(_norm(_shift(dr, -off), L))
                            off += w
                        r = _hull(parts)
        elif n.prim in ("dynamic_slice", "dynamic_update_slice", "gather",
                        "scatter", "scatter-add", "pad", "sort"):
            dep = graph.node(n.deps[0]) if n.deps else None
            has_ring_dep = any(dr not in (None,) for dr in deps_r)
            ring_indexed = dep is not None and \
                ring_axis_of(dep.aval, probe.ring_widths) is not None
            r = TOP if (has_ring_dep and ring_indexed) else _hull(deps_r)
        elif n.prim in PASSTHROUGH or n.prim in ELEMENTWISE or \
                n.prim in ("cond_join", "select_n"):
            r = _hull(deps_r)
        else:
            # unknown op: conservative only if it actually consumes tau-reach
            r = _hull(deps_r)
            if r is not None and n.prim not in ELEMENTWISE:
                r = TOP
        reach[n.gid] = r
        if r == TOP:
            top_origin[n.gid] = mark_top(n, deps_r)
    return reach, top_origin


def _fmt(r):
    if r == TOP:
        return "unbounded"
    return f"[{r[0]:+d}, {r[1]:+d}]"


def check(probe: Probe, **_) -> list:
    graph = probe.graph
    reach, top_origin = _compute_reach(graph, probe)
    findings = []

    def blame(gid, msg):
        origin = top_origin.get(gid, gid)
        n = graph.node(origin)
        findings.append(Finding(
            rule=RULE, message=msg, op=n.prim, path=where(n)))

    # per-step growth at every ring-shaped carry (scan body / pallas tile)
    for n in graph.nodes:
        if n.prim not in ("scan_carry", "ref_carry"):
            continue
        co = n.params.get("carry_out")
        if co is None:
            continue
        r_in, r_out = reach.get(n.gid), reach.get(co)
        if r_in in (None, TOP) or r_out is None:
            if r_out == TOP or r_in == TOP:
                blame(co if r_out == TOP else n.gid,
                      "ring-indexed op defeats stencil analysis on a "
                      "loop-carried tau value")
            continue
        if r_out == TOP:
            blame(co, "ring-indexed op defeats stencil analysis on a "
                      "loop-carried tau value")
            continue
        glo, ghi = r_out[0] - r_in[0], r_out[1] - r_in[1]
        if glo < -1 or ghi > 1:
            blame(co, f"per-step ring reach grows by [{glo:+d}, {ghi:+d}] "
                      "(allowed [-1, +1]): data flows beyond nearest "
                      "neighbors in one step")

    # the probe's tau output itself
    _, tau_out = tau_io(graph, probe)
    r = reach.get(tau_out)
    if r == TOP:
        blame(tau_out, "tau output depends on tau through an un-analyzable "
                       "ring-indexed op")
    elif r is not None and (r[0] < -1 or r[1] > 1):
        blame(tau_out, f"tau output reaches ring neighbors {_fmt(r)} "
                       "(allowed [-1, +1])")

    # HLO side: collective-permute source_target_pairs must be ±1 neighbors
    if probe.hlo and probe.shard_L:
        from ...launch.hlo_cost import collective_permutes
        ring_n = probe.L_ring // max(probe.shard_L.values())
        for pairs in collective_permutes(probe.hlo):
            for s, t in pairs:
                same_group = (s // ring_n) == (t // ring_n)
                dist = (t - s) % ring_n
                if not same_group or dist not in (1, ring_n - 1):
                    findings.append(Finding(
                        rule=RULE, op="collective-permute",
                        message=f"HLO collective-permute pair ({s},{t}) is "
                                f"not a ±1 ring-neighbor shift "
                                f"(ring size {ring_n})"))
                    break
    return findings

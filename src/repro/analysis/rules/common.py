"""Shared helpers for the analysis rules."""
from __future__ import annotations

import numpy as np

from ..graph import Graph, ring_axis_of
from ..probes import Probe

#: prims whose output is just their (first) input, re-viewed
PASSTHROUGH = frozenset({
    "scan_xs", "scan_stack", "shard_in", "shard_out", "pallas_block",
    "pallas_out", "ref_get", "ref_swap", "proj", "copy", "convert_element_type",
    "reshape", "squeeze", "expand_dims", "transpose", "rev", "stop_gradient",
    "broadcast_in_dim", "pvary", "pbroadcast",
})

#: order-preserving elementwise prims (hull semantics for both analyses)
ELEMENTWISE = frozenset({
    "add", "sub", "mul", "div", "max", "min", "neg", "abs", "exp", "log",
    "log1p", "sqrt", "rsqrt", "floor", "ceil", "round", "sign", "tanh",
    "logistic", "integer_pow", "pow", "rem", "remainder", "and", "or", "xor",
    "not", "shift_right_logical", "shift_left", "shift_right_arithmetic",
    "select_n", "eq", "ne", "lt", "le", "gt", "ge", "nextafter", "clamp",
    "is_finite", "square",
})

RING_REDUCE = frozenset({"reduce_min", "reduce_max", "reduce_sum",
                         "reduce_prod", "reduce_and", "reduce_or",
                         "argmin", "argmax"})

NAMED_REDUCE = frozenset({"psum", "pmin", "pmax", "all_gather",
                          "all_to_all", "psum2"})


def dep_ring_axis(graph: Graph, node, probe: Probe):
    """Ring axis index of a node's first dep, else None."""
    if not node.deps:
        return None
    return ring_axis_of(graph.node(node.deps[0]).aval, probe.ring_widths)


def named_axes(node) -> tuple:
    ax = node.params.get("axes", node.params.get("axis_name", ()))
    if isinstance(ax, (str, int)):
        ax = (ax,)
    return tuple(ax)


def is_ring_reduction(graph: Graph, node, probe: Probe) -> bool:
    """True for a reduction that collapses the ring axis (the GVT channel)."""
    if node.prim in RING_REDUCE:
        dax = dep_ring_axis(graph, node, probe)
        return dax is not None and dax in tuple(node.params.get("axes", ()))
    if node.prim in NAMED_REDUCE:
        return any(a in probe.shard_L for a in named_axes(node))
    return False


def ring_min_gids(graph: Graph, probe: Probe) -> set:
    """gids of min-reductions over the ring — the sanctioned window base."""
    out = set()
    for n in graph.nodes:
        if n.prim in ("reduce_min", "pmin") and \
                is_ring_reduction(graph, n, probe):
            out.add(n.gid)
    return out


def tau_io(graph: Graph, probe: Probe):
    """(tau input gid, tau output gid) of a probe."""
    return graph.in_gids[probe.tau_in], graph.out_gids[probe.tau_out]


def const_bounds(val):
    """(lo, hi) of a numeric constant, else None."""
    try:
        a = np.asarray(val)
        if a.size == 0 or not np.issubdtype(a.dtype, np.number):
            return None
        return float(a.min()), float(a.max())
    except Exception:
        return None


def where(node) -> str:
    """Provenance string for a finding."""
    loc = node.path or "/"
    if node.src:
        loc += f" ({node.src})"
    return loc

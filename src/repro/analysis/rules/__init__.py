"""Rule registry for the causality linter.

Every rule is a function ``check(probe, **options) -> list[Finding]``.
``ALL_RULES`` maps the public rule name (as shown in reports and accepted by
``--rules`` / ``--waive``) to its checker.
"""
from __future__ import annotations

from . import dtype_drift, monotonic, reductions, stencil, vmem, window

ALL_RULES = {
    stencil.RULE: stencil.check,
    monotonic.RULE: monotonic.check,
    window.RULE: window.check,
    dtype_drift.RULE: dtype_drift.check,
    reductions.RULE: reductions.check,
    vmem.RULE: vmem.check,
}

__all__ = ["ALL_RULES", "dtype_drift", "monotonic", "reductions", "stencil",
           "vmem", "window"]

"""CLI for the causality linter: ``python -m repro.analysis``.

Exit status 0 when every (unwaived) rule holds on every requested backend,
1 otherwise — the CI ``analysis`` job gates on this.
"""
from __future__ import annotations

import argparse
import sys

from ..core.engine import BACKENDS
from . import ALL_RULES, analyze
from .rules.vmem import DEFAULT_BUDGET


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Statically prove PDES protocol invariants and kernel "
                    "budgets over every backend's traced step function.")
    p.add_argument("--backend", default="all",
                   help="comma-separated backends, or 'all' "
                        f"(choices: {', '.join(BACKENDS)})")
    p.add_argument("--rules", default=None,
                   help="comma-separated rule subset "
                        f"(choices: {', '.join(ALL_RULES)})")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--waive", action="append", default=[],
                   metavar="RULE[:BACKEND]",
                   help="keep a finding in the report but do not fail on it "
                        "(repeatable)")
    p.add_argument("--vmem-budget", type=int, default=DEFAULT_BUDGET,
                   help="VMEM budget in bytes for the vmem-budget rule "
                        f"(default {DEFAULT_BUDGET})")
    p.add_argument("-o", "--output", default=None,
                   help="also write the JSON report to this path")
    args = p.parse_args(argv)

    backends = (BACKENDS if args.backend == "all"
                else tuple(b.strip() for b in args.backend.split(",")))
    for b in backends:
        if b not in BACKENDS:
            p.error(f"unknown backend {b!r}; choices: {', '.join(BACKENDS)}")
    rules = None
    if args.rules:
        rules = {}
        for r in args.rules.split(","):
            r = r.strip()
            if r not in ALL_RULES:
                p.error(f"unknown rule {r!r}; choices: "
                        f"{', '.join(ALL_RULES)}")
            rules[r] = ALL_RULES[r]

    report = analyze(backends=backends, rules=rules, waivers=args.waive,
                     vmem_budget=args.vmem_budget)
    if args.output:
        with open(args.output, "w") as f:
            f.write(report.to_json() + "\n")
    print(report.to_json() if args.format == "json" else report.to_text())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())

"""Seeded-violation fixtures: each proves one rule actually fires.

A linter whose rules never fire proves nothing, so each fixture here is a
small traced function with exactly one protocol violation planted in
otherwise-idiomatic step code.  ``FIXTURES`` maps fixture name to
``(probe, expected_rule)``; tests/test_analysis.py asserts the expected
rule reports a finding on its fixture (red) while the clean backends stay
green.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

from .probes import DEFAULT_DELTA, Probe, _trace


def _decode(bits, n_v, dtype):
    from ..core.horizon import decode_words
    return decode_words(bits[..., 0], bits[..., 1], n_v, dtype)


def _std_probe(name, fn, *args, delta=DEFAULT_DELTA, L=16, **kw):
    g = _trace(fn, *args)
    return Probe(name, backend=f"fixture:{name}", graph=g, tau_in=0,
                 tau_out=0, ring_widths=frozenset({L, L + 2}), L_ring=L,
                 delta=delta, delta_input=None, **kw)


def nnn_roll():
    """Leaked next-nearest-neighbor dependence: left neighbor from roll(2)."""
    from ..core.horizon import conservative_update

    def fn(tau, bits):
        is_l, is_r, eta = _decode(bits, 4, tau.dtype)
        left = jnp.roll(tau, 2, axis=-1)       # BUG: should be roll(1)
        right = jnp.roll(tau, -1, axis=-1)
        gvt = jnp.min(tau, axis=-1, keepdims=True)
        out, _ = conservative_update(tau, left, right, is_l, is_r, eta, gvt,
                                     delta=DEFAULT_DELTA)
        return out

    return _std_probe("nnn_roll", fn, jnp.zeros((4, 16), jnp.float32),
                      jnp.zeros((4, 16, 2), jnp.uint32)), "stencil-locality"


def no_window_guard():
    """Finite Δ claimed, but the advance never compares against the base."""
    from ..core.horizon import conservative_update

    def fn(tau, bits):
        is_l, is_r, eta = _decode(bits, 4, tau.dtype)
        left = jnp.roll(tau, 1, axis=-1)
        right = jnp.roll(tau, -1, axis=-1)
        gvt = jnp.min(tau, axis=-1, keepdims=True)
        # BUG: the window comparison was dropped (delta=inf short-circuits
        # Eq. (3)) while the config still claims a finite window.
        out, _ = conservative_update(tau, left, right, is_l, is_r, eta, gvt,
                                     delta=math.inf)
        return out

    return _std_probe("no_window_guard", fn,
                      jnp.zeros((4, 16), jnp.float32),
                      jnp.zeros((4, 16, 2), jnp.uint32)), "window-bound"


def decreasing_tau():
    """Unguarded tau increment that can be negative (eta - 1)."""
    from ..core.horizon import conservative_update

    def fn(tau, bits):
        is_l, is_r, eta = _decode(bits, 4, tau.dtype)
        left = jnp.roll(tau, 1, axis=-1)
        right = jnp.roll(tau, -1, axis=-1)
        gvt = jnp.min(tau, axis=-1, keepdims=True)
        out, _ = conservative_update(tau, left, right, is_l, is_r,
                                     eta - 1.0,   # BUG: may be negative
                                     gvt, delta=DEFAULT_DELTA)
        return out

    return _std_probe("decreasing_tau", fn,
                      jnp.zeros((4, 16), jnp.float32),
                      jnp.zeros((4, 16, 2), jnp.uint32)), "tau-monotonicity"


def f64_promotion():
    """Event decode computed in float64 — silently widens the whole step."""
    from ..core.horizon import conservative_update

    def fn(tau, bits):
        w0, w1 = bits[..., 0], bits[..., 1]
        site = jnp.remainder(w0, jnp.uint32(4)).astype(jnp.int32)
        # BUG: float64 decode — under x64 this propagates into tau
        u = (w1 >> jnp.uint32(8)).astype(jnp.float64) * 2.0**-24
        eta = -jnp.log(u + 2.0**-25)
        left = jnp.roll(tau, 1, axis=-1)
        right = jnp.roll(tau, -1, axis=-1)
        gvt = jnp.min(tau, axis=-1, keepdims=True)
        out, _ = conservative_update(tau, left, right, site == 0, site == 3,
                                     eta, gvt, delta=DEFAULT_DELTA)
        return out

    return _std_probe("f64_promotion", fn,
                      jnp.zeros((4, 16), jnp.float32),
                      jnp.zeros((4, 16, 2), jnp.uint32)), "dtype-drift"


def nondet_reduction():
    """Window base from a float psum (mean) instead of the order-free pmin."""
    from jax import lax
    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map
    from ..core.horizon import conservative_update
    from .probes import _abstract_mesh

    ring_n, L_l = 4, 8

    def body(tau, bits):
        # BUG: deriving the window base via a float all-reduce-sum — its
        # cross-replica order is unspecified, breaking bit parity.
        gvt = lax.psum(jnp.min(tau, axis=-1, keepdims=True),
                       "model") / ring_n
        is_l, is_r, eta = _decode(bits, 4, tau.dtype)
        fwd = [(i, (i + 1) % ring_n) for i in range(ring_n)]
        bwd = [(i, (i - 1) % ring_n) for i in range(ring_n)]
        lcol = lax.ppermute(tau[:, -1:], "model", perm=fwd)
        rcol = lax.ppermute(tau[:, :1], "model", perm=bwd)
        tau_h = jnp.concatenate([lcol, tau, rcol], axis=1)
        out, _ = conservative_update(
            tau_h[:, 1:-1], tau_h[:, :-2], tau_h[:, 2:], is_l, is_r, eta,
            gvt, delta=DEFAULT_DELTA)
        return out

    mesh = _abstract_mesh(2, ring_n)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(("data",), "model"), P(("data",), "model")),
                   out_specs=P(("data",), "model"), check_rep=False)
    L = ring_n * L_l
    g = _trace(fn, jnp.zeros((4, L), jnp.float32),
               jnp.zeros((4, L, 2), jnp.uint32))
    probe = Probe("nondet_reduction", backend="fixture:nondet_reduction",
                  graph=g, tau_in=0, tau_out=0,
                  ring_widths=frozenset({L, L_l, L_l + 2}), L_ring=L,
                  delta=DEFAULT_DELTA, delta_input=None,
                  shard_L={"model": L_l})
    return probe, "nondeterministic-reduction"


def vmem_blowup():
    """Kernel tiles far beyond any VMEM budget (whole 1M-site rings)."""
    import jax

    from ..kernels.pdes_step import pdes_step

    B, Lc = 8, 1 << 20

    def fn(tau_h, bits, gvt):
        out, _ = pdes_step(tau_h, bits, gvt, n_v=4, delta=DEFAULT_DELTA,
                           block_b=B, interpret=True)
        return out

    g = _trace(fn, jax.numpy.zeros((B, Lc + 2), jax.numpy.float32),
               jax.numpy.zeros((B, Lc, 2), jax.numpy.uint32),
               jax.numpy.zeros((B, 1), jax.numpy.float32))
    probe = Probe("vmem_blowup", backend="fixture:vmem_blowup", graph=g,
                  tau_in=0, tau_out=0,
                  ring_widths=frozenset({Lc, Lc + 2}), L_ring=Lc,
                  delta=DEFAULT_DELTA, delta_input=None)
    return probe, "vmem-budget"


FIXTURES = {
    "nnn_roll": nnn_roll,
    "no_window_guard": no_window_guard,
    "decreasing_tau": decreasing_tau,
    "f64_promotion": f64_promotion,
    "nondet_reduction": nondet_reduction,
    "vmem_blowup": vmem_blowup,
}

"""Findings, waivers, and report formatting for the causality linter.

A rule emits :class:`Finding`\\ s; the per-backend driver collects them into a
:class:`BackendReport`; ``analyze`` (see ``__init__``) aggregates those into a
:class:`Report` whose ``ok`` property is the CI gate.  A finding names the
rule that fired, the backend/probe it fired on, and — when the rule can trace
it — the jaxpr op (primitive + provenance path) that violated the invariant.

Waivers: a waiver is ``"rule"`` or ``"rule:backend"``.  Waived findings stay
in the report (marked ``waived: true``) but do not fail the gate, so a known
exception is visible in the artifact instead of silently dropped.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation (or suspicion) with op provenance."""

    rule: str                 # e.g. "stencil-locality"
    message: str              # human-readable description of the violation
    backend: str = ""         # filled in by the driver
    probe: str = ""           # which traced entry point ("step", "sweep", ...)
    op: str = ""              # offending primitive, e.g. "roll" / "ppermute"
    path: str = ""            # provenance path inside the jaxpr, if known
    waived: bool = False

    def with_context(self, backend: str, probe: str) -> "Finding":
        return dataclasses.replace(self, backend=backend, probe=probe)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items() if v not in ("", False)}


@dataclasses.dataclass
class BackendReport:
    """All findings for one backend (every probe always runs)."""

    backend: str
    findings: list = dataclasses.field(default_factory=list)
    rules_run: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not [f for f in self.findings if not f.waived]

    def to_dict(self) -> dict:
        return {
            "backend": self.backend,
            "ok": self.ok,
            "rules_run": sorted(set(self.rules_run)),
            "findings": [f.to_dict() for f in self.findings],
        }


@dataclasses.dataclass
class Report:
    """Aggregate over backends — what the CLI prints and CI gates on."""

    backends: list = dataclasses.field(default_factory=list)
    waivers: tuple = ()

    @property
    def ok(self) -> bool:
        return all(b.ok for b in self.backends)

    @property
    def findings(self) -> list:
        return [f for b in self.backends for f in b.findings]

    def to_dict(self) -> dict:
        return {
            "ok": self.ok,
            "n_findings": len([f for f in self.findings if not f.waived]),
            "waivers": list(self.waivers),
            "backends": [b.to_dict() for b in self.backends],
        }

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    def to_text(self) -> str:
        lines = []
        for b in self.backends:
            status = "OK" if b.ok else "FAIL"
            lines.append(f"[{status}] backend={b.backend} "
                         f"rules={','.join(sorted(set(b.rules_run)))}")
            for f in b.findings:
                tag = " (waived)" if f.waived else ""
                loc = f" at {f.op}" if f.op else ""
                if f.path:
                    loc += f" [{f.path}]"
                lines.append(
                    f"    {f.rule}{tag} probe={f.probe}{loc}: {f.message}")
        verdict = "PASS" if self.ok else "FAIL"
        n = len([f for f in self.findings if not f.waived])
        lines.append(f"analysis: {verdict} ({n} unwaived finding(s), "
                     f"{len(self.backends)} backend(s))")
        return "\n".join(lines)


def parse_waivers(items) -> tuple:
    """Normalize waiver strings ``rule`` / ``rule:backend``."""
    out = []
    for it in items or ():
        it = it.strip()
        if it:
            out.append(it)
    return tuple(out)


def is_waived(finding: Finding, waivers) -> bool:
    for w in waivers or ():
        rule, _, backend = w.partition(":")
        if rule != finding.rule:
            continue
        if not backend or backend == finding.backend:
            return True
    return False


def apply_waivers(findings, waivers) -> list:
    return [dataclasses.replace(f, waived=is_waived(f, waivers))
            for f in findings]


def summary_verdict(report: Report) -> dict[str, Any]:
    """Compact verdict for embedding in bench JSON metadata."""
    return {
        "ok": report.ok,
        "n_findings": len([f for f in report.findings if not f.waived]),
        "backends": {b.backend: b.ok for b in report.backends},
    }

"""Jaxpr flattening: one dataflow graph across every nesting construct.

``jax.make_jaxpr`` gives a *nested* program — ``pjit`` / ``scan`` / ``cond``
/ ``shard_map`` / ``pallas_call`` equations each carry sub-jaxprs with their
own variable namespaces.  The rules want plain dataflow questions ("does the
tau output depend on a roll by 2", "is there a float psum on the tau path"),
so this module inlines everything into a single :class:`Graph` of
:class:`Node`\\ s with global ids.

Inlining semantics (what the rules rely on):

* ``pjit`` / ``closed_call`` / ``custom_jvp_call`` / ``remat``: transparent —
  the body is spliced in, provenance path extended with the jit name.
* ``scan`` / ``while``: the body is inlined **once**.  Each carry component
  gets a synthetic ``scan_carry`` node (dep: the init value) whose
  ``params["carry_out"]`` is patched to the body's output for that slot —
  rules formulate per-step invariants (e.g. stencil growth per step) against
  these pairs.  ``xs`` inputs appear as ``scan_xs`` (leading axis dropped),
  stacked ys outputs as ``scan_stack``.
* ``cond``: all branches are inlined; every output becomes a ``cond_join``
  node over the predicate and the per-branch values.  Branches that mutate
  refs (``pl.when``) join the final cell values the same way.
* ``shard_map``: body inlined; operands enter via ``shard_in`` nodes (aval
  becomes the shard-local block) and leave via ``shard_out``.
* ``pallas_call``: the kernel jaxpr is inlined with *ref-cell* semantics:
  each input ref's cell starts at a ``pallas_block`` node wrapping the
  operand, each output ref's cell starts at a synthetic ``ref_carry`` node
  (the revisited-tile fixpoint seed — same role as ``scan_carry``);
  ``get`` reads the cell, ``swap`` writes it, and the call's outputs are
  ``pallas_out`` nodes over the final cells.  The ``pallas_call`` node
  itself is kept (deps: operands) carrying ``grid_mapping`` for the VMEM
  rule.

The graph is an over-approximation: a rule that finds *no* violating path
has proven the invariant for the traced shapes; unknown constructs degrade
to conservative "unanalyzable" nodes rather than silently passing.
"""
from __future__ import annotations

import dataclasses
from typing import Any

try:
    from jax.extend.core import Literal
except ImportError:  # older jax
    from jax.core import Literal


@dataclasses.dataclass
class Node:
    gid: int
    prim: str
    deps: list
    aval: Any = None          # output ShapedArray (or None)
    params: dict = dataclasses.field(default_factory=dict)
    path: str = ""            # provenance: nesting path, e.g. "/pjit:one/scan"
    src: str = ""             # best-effort source location "file:line"

    def describe(self) -> str:
        shape = getattr(self.aval, "shape", None)
        dt = getattr(self.aval, "dtype", None)
        s = f"{self.prim}"
        if shape is not None:
            s += f" -> {dt}{list(shape)}"
        return s


class _RefCell:
    """Mutable cell standing in for a pallas ref during inlining."""

    __slots__ = ("cell",)

    def __init__(self, cell: int):
        self.cell = cell


@dataclasses.dataclass
class Graph:
    nodes: list
    in_gids: list
    out_gids: list

    def node(self, gid: int) -> Node:
        return self.nodes[gid]

    def ancestors(self, gid: int) -> set:
        """All gids reachable backwards from ``gid`` (inclusive)."""
        seen, stack = set(), [gid]
        while stack:
            g = stack.pop()
            if g in seen:
                continue
            seen.add(g)
            stack.extend(self.nodes[g].deps)
        return seen

    def find(self, prim: str) -> list:
        return [n for n in self.nodes if n.prim == prim]


def _src_of(eqn) -> str:
    try:
        from jax._src import source_info_util
        frame = source_info_util.user_frame(eqn.source_info.traceback)
        if frame is not None:
            return f"{frame.file_name.rsplit('/', 1)[-1]}:{frame.start_line}"
    except Exception:
        pass
    return ""


def _inner_aval(aval):
    """AbstractRef -> carried array aval; plain avals pass through."""
    return getattr(aval, "inner_aval", aval)


def _sub_jaxpr(params, *keys):
    for k in keys:
        if k in params and params[k] is not None:
            return params[k]
    return None


def _as_closed(j):
    """(jaxpr, consts) from either a ClosedJaxpr or a raw Jaxpr."""
    if hasattr(j, "jaxpr"):
        return j.jaxpr, list(j.consts)
    return j, []


class _Builder:
    def __init__(self):
        self.nodes: list[Node] = []

    def add(self, prim, deps, aval=None, params=None, path="", src="") -> int:
        gid = len(self.nodes)
        self.nodes.append(Node(gid, prim, [d for d in deps if d is not None],
                               aval, params or {}, path, src))
        return gid

    # -- one jaxpr body ----------------------------------------------------

    def inline(self, jaxpr, consts, invals, path: str) -> list:
        """Inline ``jaxpr``; invals are gids or _RefCells.  Returns outvals."""
        env: dict = {}

        def read(atom):
            if isinstance(atom, Literal):
                return self.add("const", [], aval=atom.aval,
                                params={"val": atom.val}, path=path)
            return env[atom]

        for var, cval in zip(jaxpr.constvars, consts):
            aval = getattr(cval, "aval", None) or getattr(var, "aval", None)
            env[var] = self.add("const", [], aval=aval,
                                params={"val": cval}, path=path)
        for var, v in zip(jaxpr.invars, invals):
            env[var] = v

        for eqn in jaxpr.eqns:
            invals_e = [read(a) for a in eqn.invars]
            outs = self.eqn(eqn, invals_e, path)
            for var, o in zip(eqn.outvars, outs):
                if type(var).__name__ != "DropVar":
                    env[var] = o
        return [read(v) for v in jaxpr.outvars]

    # -- one equation ------------------------------------------------------

    def eqn(self, eqn, invals, path: str) -> list:
        name = eqn.primitive.name
        src = _src_of(eqn)
        params = dict(eqn.params)
        out_avals = [v.aval for v in eqn.outvars]

        if name in ("pjit", "closed_call", "core_call", "xla_call",
                    "remat", "checkpoint", "custom_jvp_call",
                    "custom_vjp_call", "custom_vjp_call_jaxpr"):
            sub = _sub_jaxpr(params, "jaxpr", "call_jaxpr", "fun_jaxpr")
            if sub is not None:
                j, consts = _as_closed(sub)
                label = params.get("name", name)
                return self.inline(j, consts, invals, f"{path}/{label}")

        if name == "scan":
            return self._scan(eqn, invals, path, src)
        if name == "while":
            return self._while(eqn, invals, path, src)
        if name == "cond":
            return self._cond(eqn, invals, path, src)
        if name == "shard_map":
            return self._shard_map(eqn, invals, path, src)
        if name == "pallas_call":
            return self._pallas(eqn, invals, path, src)

        if name == "get":
            ref = invals[0]
            if isinstance(ref, _RefCell):
                extra = [v for v in invals[1:] if not isinstance(v, _RefCell)]
                g = self.add("ref_get", [ref.cell] + extra,
                             aval=out_avals[0], params=params,
                             path=path, src=src)
                return [g]
        if name == "swap":
            ref, val = invals[0], invals[1]
            if isinstance(ref, _RefCell):
                old = ref.cell
                extra = [v for v in invals[2:] if not isinstance(v, _RefCell)]
                ref.cell = self.add("ref_swap", [val] + extra,
                                    aval=_inner_aval(eqn.invars[0].aval),
                                    params=params, path=path, src=src)
                return [self.add("ref_get", [old], aval=out_avals[0],
                                 path=path, src=src)]

        deps = [v.cell if isinstance(v, _RefCell) else v for v in invals]
        gid = self.add(name, deps, aval=out_avals[0] if out_avals else None,
                       params=params, path=path, src=src)
        if len(out_avals) <= 1:
            return [gid]
        return [self.add("proj", [gid], aval=a,
                         params={"index": i}, path=path, src=src)
                for i, a in enumerate(out_avals)]

    # -- structured constructs --------------------------------------------

    def _scan(self, eqn, invals, path, src):
        p = eqn.params
        j, consts = _as_closed(p["jaxpr"])
        nc, ncar = p["num_consts"], p["num_carry"]
        cvals = invals[:nc]
        carry_nodes = []
        body_in = list(cvals)
        for i, init in enumerate(invals[nc:nc + ncar]):
            g = self.add("scan_carry", [init],
                         aval=j.invars[nc + i].aval,
                         params={"slot": i}, path=path, src=src)
            carry_nodes.append(g)
            body_in.append(g)
        for i, xs in enumerate(invals[nc + ncar:]):
            body_in.append(self.add("scan_xs", [xs],
                                    aval=j.invars[nc + ncar + i].aval,
                                    path=path, src=src))
        outs = self.inline(j, consts, body_in, f"{path}/scan")
        carry_out, ys = outs[:ncar], outs[ncar:]
        for g, co in zip(carry_nodes, carry_out):
            self.nodes[g].params["carry_out"] = co
        res = list(carry_out)
        for i, y in enumerate(ys):
            res.append(self.add("scan_stack", [y],
                                aval=eqn.outvars[ncar + i].aval,
                                path=path, src=src))
        return res

    def _while(self, eqn, invals, path, src):
        p = eqn.params
        cj, cconsts = _as_closed(p["cond_jaxpr"])
        bj, bconsts = _as_closed(p["body_jaxpr"])
        cn, bn = p["cond_nconsts"], p["body_nconsts"]
        carry_init = invals[cn + bn:]
        carry_nodes = [
            self.add("scan_carry", [init], aval=v.aval,
                     params={"slot": i}, path=path, src=src)
            for i, (init, v) in enumerate(
                zip(carry_init, bj.invars[bn:]))]
        self.inline(cj, cconsts, invals[:cn] + carry_nodes, f"{path}/while_cond")
        outs = self.inline(bj, bconsts, invals[cn:cn + bn] + carry_nodes,
                           f"{path}/while")
        for g, co in zip(carry_nodes, outs):
            self.nodes[g].params["carry_out"] = co
        return outs

    def _cond(self, eqn, invals, path, src):
        branches = eqn.params["branches"]
        pred, ops = invals[0], invals[1:]
        ref_slots = [i for i, v in enumerate(ops) if isinstance(v, _RefCell)]
        snapshot = {i: ops[i].cell for i in ref_slots}
        branch_outs, branch_cells = [], []
        for bi, br in enumerate(branches):
            j, consts = _as_closed(br)
            for i in ref_slots:          # each branch starts from the snapshot
                ops[i].cell = snapshot[i]
            outs = self.inline(j, consts, ops, f"{path}/cond{bi}")
            branch_outs.append(outs)
            branch_cells.append({i: ops[i].cell for i in ref_slots})
        for i in ref_slots:
            cells = [bc[i] for bc in branch_cells]
            if len(set(cells)) > 1:
                ops[i].cell = self.add(
                    "cond_join", [pred] + cells,
                    aval=_inner_aval(eqn.invars[1 + i].aval),
                    path=path, src=src)
        res = []
        for k, var in enumerate(eqn.outvars):
            vals = [bo[k] for bo in branch_outs]
            if len(set(vals)) == 1:
                res.append(vals[0])
            else:
                res.append(self.add("cond_join", [pred] + vals,
                                    aval=var.aval, path=path, src=src))
        return res

    def _shard_map(self, eqn, invals, path, src):
        p = eqn.params
        j, consts = _as_closed(p["jaxpr"])
        in_names = p.get("in_names") or [{}] * len(invals)
        body_in = [
            self.add("shard_in", [v], aval=var.aval,
                     params={"names": dict(n) if hasattr(n, "items") else n},
                     path=path, src=src)
            for v, var, n in zip(invals, j.invars, in_names)]
        outs = self.inline(j, consts, body_in, f"{path}/shard_map")
        return [self.add("shard_out", [o], aval=var.aval, path=path, src=src)
                for o, var in zip(outs, eqn.outvars)]

    def _pallas(self, eqn, invals, path, src):
        p = eqn.params
        j, consts = _as_closed(p["jaxpr"])
        n_out = len(eqn.outvars)
        n_in = len(invals)
        # keep the call node itself: the VMEM rule reads grid_mapping off it
        call = self.add("pallas_call", list(invals), aval=None,
                        params={"grid_mapping": p.get("grid_mapping"),
                                "name": getattr(
                                    p.get("name_and_src_info", None), "name",
                                    p.get("name", ""))},
                        path=path, src=src)
        cells = []
        for i, v in enumerate(invals):
            aval = _inner_aval(j.invars[i].aval)
            cells.append(_RefCell(self.add(
                "pallas_block", [v], aval=aval,
                params={"operand": i}, path=path, src=src)))
        out_cells, seeds = [], []
        for i in range(n_out):
            aval = _inner_aval(j.invars[n_in + i].aval)
            seed = self.add("ref_carry", [], aval=aval,
                            params={"slot": i}, path=path, src=src)
            seeds.append(seed)
            c = _RefCell(seed)
            out_cells.append(c)
            cells.append(c)
        kname = self.nodes[call].params["name"] or "kernel"
        self.inline(j, consts, cells, f"{path}/pallas:{kname}")
        res = []
        for i, c in enumerate(out_cells):
            # the revisited-tile fixpoint: seed's carry_out = final cell value
            self.nodes[seeds[i]].params["carry_out"] = c.cell
            res.append(self.add("pallas_out", [c.cell, call],
                                aval=eqn.outvars[i].aval, path=path, src=src))
        return res


def build_graph(closed_jaxpr) -> Graph:
    """Flatten a ClosedJaxpr from ``jax.make_jaxpr`` into a :class:`Graph`."""
    b = _Builder()
    j = closed_jaxpr.jaxpr
    in_gids = [b.add("input", [], aval=v.aval, params={"index": i})
               for i, v in enumerate(j.invars)]
    out_gids = b.inline(j, list(closed_jaxpr.consts), in_gids, "")
    # outputs may be _RefCells in pathological cases; resolve
    out_gids = [o.cell if isinstance(o, _RefCell) else o for o in out_gids]
    return Graph(b.nodes, in_gids, out_gids)


def ring_axis_of(aval, ring_widths) -> int | None:
    """Axis index whose extent is a known ring width, else None.

    Probe shapes are chosen so ring widths collide with no other extent,
    making this lookup unambiguous (see probes.py).
    """
    shape = getattr(aval, "shape", None)
    if not shape:
        return None
    for ax in range(len(shape) - 1, -1, -1):   # ring rides the minor axis
        if shape[ax] in ring_widths:
            return ax
    return None

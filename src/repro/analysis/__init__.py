"""repro.analysis — the causality linter.

A static-analysis pass that traces each backend's step function to a jaxpr
(and, for the sharded backend, lowered HLO) and proves the paper's protocol
invariants plus kernel budgets over the traced computation:

==============================  =============================================
rule                            invariant
==============================  =============================================
``stencil-locality``            tau updates reach only {i-1, i, i+1} ring
                                neighbors (rolls/slices/halos in the jaxpr,
                                collective-permute pairs in sharded HLO)
``tau-monotonicity``            no dataflow path can decrease a local
                                virtual time
``window-bound``                finite-Δ advances are dominated by a
                                comparison against the window base
                                (including the ``deltas=`` sweep operand)
``dtype-drift``                 no silent f32→f64 / i32→i64 promotion
``nondeterministic-reduction``  no order-unspecified float collective on
                                the trajectory path
``vmem-budget``                 per-BlockSpec VMEM footprint of each Pallas
                                kernel within budget
==============================  =============================================

Usage::

    python -m repro.analysis --backend all --format text
    python -m repro.analysis --backend sharded --format json -o report.json

or programmatically::

    from repro.analysis import analyze
    report = analyze()          # all backends, all rules
    assert report.ok, report.to_text()
"""
from __future__ import annotations

from ..core.engine import BACKENDS
from .probes import Probe, iter_probes
from .report import (BackendReport, Finding, Report, apply_waivers,
                     parse_waivers, summary_verdict)
from .rules import ALL_RULES

__all__ = ["ALL_RULES", "BACKENDS", "BackendReport", "Finding", "Probe",
           "Report", "analysis_verdict", "analyze", "analyze_backend",
           "analyze_probe", "iter_probes"]


def analyze_probe(probe: Probe, rules=None, **options) -> list:
    """Run rules over one probe; returns contextualized findings."""
    selected = rules or ALL_RULES
    out = []
    for name, fn in selected.items():
        for f in fn(probe, **options):
            out.append(f.with_context(probe.backend, probe.name))
    return out


def analyze_backend(backend: str, rules=None, waivers=(),
                    **options) -> BackendReport:
    """Trace every probe of one backend and run the rule engine."""
    selected = rules or ALL_RULES
    rep = BackendReport(backend=backend, rules_run=list(selected))
    for probe in iter_probes(backend):
        rep.findings.extend(analyze_probe(probe, selected, **options))
    rep.findings = apply_waivers(rep.findings, waivers)
    return rep


def analyze(backends=None, rules=None, waivers=(), **options) -> Report:
    """Run the full pass.  ``backends=None`` means all four."""
    if backends is None or backends == "all" or backends == ("all",):
        backends = BACKENDS
    elif isinstance(backends, str):
        backends = (backends,)
    waivers = parse_waivers(waivers)
    rep = Report(waivers=waivers)
    for b in backends:
        rep.backends.append(
            analyze_backend(b, rules=rules, waivers=waivers, **options))
    return rep


_VERDICT_CACHE: dict = {}


def analysis_verdict(backends=None) -> dict:
    """Compact pass/fail verdict for embedding in bench JSON metadata.

    Cached per backend tuple — benchmarks call this once per run, not once
    per bench.  Never raises: a crashed analysis is itself a failing verdict.
    """
    key = tuple(BACKENDS if backends is None else backends)
    if key not in _VERDICT_CACHE:
        try:
            _VERDICT_CACHE[key] = summary_verdict(analyze(backends=key))
        except Exception as e:  # pragma: no cover - defensive
            _VERDICT_CACHE[key] = {"ok": False, "error": repr(e)}
    return _VERDICT_CACHE[key]

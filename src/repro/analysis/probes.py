"""Traced entry points ("probes") the rules run against.

A probe is one backend step function traced to a jaxpr at analysis shapes,
plus the metadata the rules need to interpret it: which flat input/output is
``tau``, which array extents are ring widths, the total ring size (for
mod-L wrap normalization), the per-shard ring length of each mesh axis, and
where the window inputs live.

Probe shapes are chosen so that ring widths collide with no other extent
(``B=4`` trials, ``n_v=4``, ``k=2`` fused steps against rings of 16/32
sites), making the "which axis is the ring" lookup in ``graph.ring_axis_of``
unambiguous.

All tracing happens under ``jax.experimental.enable_x64`` — with 64-bit
types *available*, any silent f32→f64 / i32→i64 promotion in the traced code
materializes as a 64-bit aval, which is exactly what the dtype-drift rule
scans for.  The clean tree is dtype-disciplined, so the graphs stay pure
f32/i32/u32.

The ``sharded`` backend is traced on an :class:`jax.sharding.AbstractMesh`
(no devices needed); its HLO text (with ``collective-permute``
``source_target_pairs``) comes from the same abstract lowering.  Every
backend — ``sharded`` included, since multi-device sweep sharding landed —
yields a sweep probe whose per-row Δ column is a traced operand, so the
window-bound rule can prove the guard compares against *that* operand on
every advance site.  The ``service`` probe traces the coalesced-batch form
on top of that (``repro.service``): the per-row trial-index vector rides
along as a traced operand, so the invariants are proven for multiplexed
passes too — rows with arbitrary global stream indices and mixed Δs.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from ..core.engine import BACKENDS, EngineConfig, _make_advance
from ..core.horizon import PDESConfig
from .graph import Graph, build_graph

DEFAULT_DELTA = 8.0


@dataclasses.dataclass
class Probe:
    """One traced entry point + the metadata rules interpret it with."""

    name: str                 # "step" | "sweep" | "stale" | "service" | "vmem"
    backend: str
    graph: Graph
    tau_in: int               # flat input index of tau
    tau_out: int              # flat output index of tau
    ring_widths: frozenset    # array extents that mean "ring axis"
    L_ring: int               # total ring size (mod-L wrap normalization)
    delta: float | None       # static window width (None = inf)
    delta_input: int | None   # flat input index of the per-row Δ column
    shard_L: dict = dataclasses.field(default_factory=dict)  # axis -> L_local
    hlo: str | None = None    # lowered HLO text (sharded probes)
    dtype: str = "float32"    # declared base dtype of tau
    trial_input: int | None = None   # flat input index of the trial vector


def _trace(fn, *args):
    from jax.experimental import enable_x64
    with enable_x64():
        return build_graph(jax.make_jaxpr(fn)(*args))


def _single_probes(backend: str):
    """step/sweep (+ production-shape vmem) probes for one-device backends."""
    B, L, K = 4, 16, 2
    cfg = PDESConfig(L=L, n_v=4, delta=DEFAULT_DELTA)
    for name, window in (("step", "exact"), ("stale", "stale")):
        if backend == "pallas_multistep" and window == "stale":
            continue       # rejected by EngineConfig: exact-GVT only
        ecfg = EngineConfig(backend=backend, window=window, k_fuse=K,
                            interpret=True)
        advance = _make_advance(cfg, ecfg, B, L)

        def fn(tau, step0, seed, b0, advance=advance):
            return advance(tau, step0, seed, K, None, b0)

        g = _trace(fn, jnp.zeros((B, L), jnp.float32), jnp.int32(0),
                   jnp.uint32(0), jnp.int32(0))
        yield Probe(name, backend, g, tau_in=0, tau_out=0,
                    ring_widths=frozenset({L, L + 2}), L_ring=L,
                    delta=cfg.delta, delta_input=None)

    ecfg = EngineConfig(backend=backend, window="exact", k_fuse=K,
                        interpret=True)
    advance = _make_advance(cfg, ecfg, B, L)

    def fn(tau, step0, seed, delta_col, b0, advance=advance):
        return advance(tau, step0, seed, K, delta_col, b0)

    g = _trace(fn, jnp.zeros((B, L), jnp.float32), jnp.int32(0),
               jnp.uint32(0), jnp.full((B, 1), DEFAULT_DELTA, jnp.float32),
               jnp.int32(0))
    yield Probe("sweep", backend, g, tau_in=0, tau_out=0,
                ring_widths=frozenset({L, L + 2}), L_ring=L,
                delta=0.0, delta_input=3)

    # the coalesced-batch form (repro.service): per-row Δ column plus a
    # per-row trial-index vector instead of a scalar stream base
    g = _trace(fn, jnp.zeros((B, L), jnp.float32), jnp.int32(0),
               jnp.uint32(0), jnp.full((B, 1), DEFAULT_DELTA, jnp.float32),
               jnp.arange(B, dtype=jnp.int32))
    yield Probe("service", backend, g, tau_in=0, tau_out=0,
                ring_widths=frozenset({L, L + 2}), L_ring=L,
                delta=0.0, delta_input=3, trial_input=4)

    if backend in ("pallas", "pallas_multistep"):
        # production-shape trace: the VMEM rule sizes real BlockSpecs here
        Bp, Lp, Kp = 64, 1024, 16
        cfgp = PDESConfig(L=Lp, n_v=4, delta=DEFAULT_DELTA)
        ecfg = EngineConfig(backend=backend, window="exact", k_fuse=Kp,
                            interpret=True)
        advance = _make_advance(cfgp, ecfg, Bp, Lp)

        def fn(tau, step0, seed, b0, advance=advance, Kp=Kp):
            return advance(tau, step0, seed, Kp, None, b0)

        g = _trace(fn, jnp.zeros((Bp, Lp), jnp.float32), jnp.int32(0),
                   jnp.uint32(0), jnp.int32(0))
        yield Probe("vmem", backend, g, tau_in=0, tau_out=0,
                    ring_widths=frozenset({Lp, Lp + 2}), L_ring=Lp,
                    delta=cfgp.delta, delta_input=None)


def _abstract_mesh(ens: int, ring: int):
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh((("data", ens), ("model", ring)))
    except TypeError:      # older signature: axis_shapes, axis_names
        return AbstractMesh((ens, ring), ("data", "model"))


def _sharded_probes():
    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map
    from ..core.distributed import STAT_KEYS, DistConfig, _shard_body

    B, L, ens, ring = 4, 32, 2, 4
    L_l = L // ring
    cfg = PDESConfig(L=L, n_v=4, delta=DEFAULT_DELTA)
    mesh = _abstract_mesh(ens, ring)
    # (name, mode, K, with Δ-column sweep operand, with trial-vector operand)
    for name, mode, K, sweep, trial in (
            ("step", "exact", 2, False, False),
            ("stale", "commavoid", 4, False, False),
            ("sweep", "exact", 2, True, False),
            ("service", "exact", 2, True, True)):
        dist = DistConfig(mode=mode, k_chunk=K)
        if trial:
            def fn(tau0, off0, comp0, seed, step0, b0, dcol, tcol,
                   dist=dist):
                return _shard_body(tau0, off0, comp0, seed, step0, b0,
                                   dcol, tcol, cfg=cfg, dist=dist,
                                   n_steps=K, L_total=L)
        else:
            fn = functools.partial(_shard_body, cfg=cfg, dist=dist,
                                   n_steps=K, L_total=L)
        in_specs = (P(dist.ens_axes, dist.ring_axis), P(dist.ens_axes),
                    P(dist.ens_axes), P(), P(), P())
        shapes = [jax.ShapeDtypeStruct((B, L), jnp.float32),
                  jax.ShapeDtypeStruct((B,), jnp.float32),
                  jax.ShapeDtypeStruct((B,), jnp.float32),
                  jax.ShapeDtypeStruct((), jnp.uint32),
                  jax.ShapeDtypeStruct((), jnp.int32),
                  jax.ShapeDtypeStruct((), jnp.int32)]
        if sweep:
            # the Δ column shards over the ensemble axes like the tau rows
            in_specs += (P(dist.ens_axes),)
            shapes.append(jax.ShapeDtypeStruct((B,), jnp.float32))
        if trial:
            # ...as does the coalesced-batch per-row trial-index vector
            in_specs += (P(dist.ens_axes),)
            shapes.append(jax.ShapeDtypeStruct((B,), jnp.int32))
        shard_fn = shard_map(
            fn, mesh=mesh,
            in_specs=in_specs,
            out_specs=(P(dist.ens_axes, dist.ring_axis), P(dist.ens_axes),
                       P(dist.ens_axes),
                       (P(None, dist.ens_axes),) * len(STAT_KEYS)),
            check_rep=False)
        args = [jnp.zeros(s.shape, s.dtype) for s in shapes]
        if trial:
            args[7] = jnp.arange(B, dtype=jnp.int32)
        if sweep:
            args[6] = jnp.full((B,), DEFAULT_DELTA, jnp.float32)
        g = _trace(shard_fn, *args)
        hlo = None
        try:
            hlo = jax.jit(shard_fn).lower(*shapes).as_text(dialect="hlo")
        except Exception:  # lowering is best-effort; jaxpr rules still run
            pass
        widths = {L, L_l, L_l + 2}
        if mode == "commavoid":
            widths |= {L_l + 2 * K, L_l + 2 * K + 2}
        yield Probe(name, "sharded", g, tau_in=0, tau_out=0,
                    ring_widths=frozenset(widths), L_ring=L,
                    delta=0.0 if sweep else cfg.delta,
                    delta_input=6 if sweep else None,
                    trial_input=7 if trial else None,
                    shard_L={"model": L_l}, hlo=hlo)


def iter_probes(backend: str):
    """Yield every :class:`Probe` of one backend."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if backend == "sharded":
        yield from _sharded_probes()
    else:
        yield from _single_probes(backend)

"""repro: Δ-window constrained conservative PDES framework (PRE 67, 046703) in JAX."""
__version__ = "1.0.0"

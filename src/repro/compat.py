"""Version-compatibility shims over drifting JAX APIs.

The codebase targets the newest JAX surface (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``lax.pcast``) but must also run on
older installed versions where those names do not exist yet.  Every call
site goes through this module so the fallbacks live in exactly one place:

* ``make_mesh(shape, axes)`` — passes explicit ``AxisType.Auto`` axis types
  where the installed JAX supports them, and falls back to plain mesh axis
  names (the pre-``AxisType`` behavior, semantically identical for every
  mesh built here) otherwise.
* ``shard_map(...)`` — prefers ``jax.shard_map``; falls back to
  ``jax.experimental.shard_map.shard_map``.  ``check_rep`` is honored only
  by the experimental API (the new API replaces it with varying-type
  inference driven by ``pcast``).
* ``pcast_varying(x, axes)`` — marks ``x`` as varying over ``axes`` for the
  new shard_map type system; a no-op on versions without ``lax.pcast``
  (their shard_map has no varying types, so there is nothing to mark —
  pair it with ``check_rep=False`` when the carry changes replication).
"""
from __future__ import annotations

import jax


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` with explicit Auto axis types when available."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                axis_shapes, axis_names,
                axis_types=(axis_type.Auto,) * len(axis_names))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(axis_shapes, axis_names)


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool | None = None):
    """``jax.shard_map`` when present, else the experimental implementation.

    ``check_rep`` is forwarded under whichever spelling the installed
    signature accepts (``check_rep``/``check_vma``); versions where
    replication checking is always-on rely on ``pcast_varying`` instead.
    """
    import inspect
    if hasattr(jax, "shard_map"):
        impl = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as impl
    kw = {}
    if check_rep is not None:
        params = inspect.signature(impl).parameters
        for name in ("check_rep", "check_vma"):
            if name in params:
                kw[name] = check_rep
                break
    return impl(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def axis_size(axis_name):
    """Static mesh-axis size inside shard_map (``lax.axis_size`` fallback).

    ``lax.psum(1, name)`` is special-cased to constant-fold to the axis size
    on versions predating ``lax.axis_size``.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def pcast_varying(x, axis_names):
    """Mark ``x`` varying over ``axis_names`` (no-op without ``lax.pcast``)."""
    pcast = getattr(jax.lax, "pcast", None)
    if pcast is not None:
        return pcast(x, axis_names, to="varying")
    return x

"""internvl2-76b [vlm]: InternViT + InternLM2 backbone (arXiv:2404.16821).

The ViT frontend is a STUB: input_specs provides precomputed patch embeddings
(B, S, d) for train/prefill; decode consumes text tokens.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=128256,
    rope_theta=1_000_000.0, tie_embeddings=False,
    input_mode="embeddings",
)

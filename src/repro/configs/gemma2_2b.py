"""gemma2-2b [dense]: local/global alternating SWA + logit softcaps (arXiv:2408.00118)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab_size=256000,
    window=4096, layer_group=("local", "full"),
    attn_softcap=50.0, final_softcap=30.0,
    act="gelu", post_norms=True, embed_scale=True,
    rope_theta=10_000.0, tie_embeddings=True,
)

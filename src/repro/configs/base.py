"""Config dataclasses: model architecture, input shapes, run settings."""
from __future__ import annotations

import dataclasses
from typing import Optional

from ..models.moe import MoESpec
from ..models.ssm import SSMSpec


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    window: Optional[int] = None          # SWA width (tokens)
    layer_group: tuple[str, ...] = ("full",)   # repeating per-layer kinds
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    attn_impl: str = "flash"       # flash | blockwise | packed (§Perf lever)
    q_block: int = 512
    k_block: int = 512
    # ffn
    act: str = "silu"
    gated_mlp: bool = True
    moe: Optional[MoESpec] = None
    # ssm / hybrid
    ssm: Optional[SSMSpec] = None
    hybrid_period: Optional[int] = None   # zamba2: shared attn every N ssm layers
    # enc-dec
    encoder_layers: int = 0
    pos_table_len: int = 0                # learned decoder positions (whisper)
    # embeddings / norm
    input_mode: str = "tokens"            # tokens | embeddings (stub frontend)
    tie_embeddings: bool = True
    embed_scale: bool = False             # multiply embeddings by sqrt(d)
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    norm_eps: float = 1e-5
    post_norms: bool = False              # gemma2 post-attn/post-mlp norms
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "full"                   # none | full | dots
    ce_chunk: int = 256
    # training
    microbatches: int = 1                 # gradient-accumulation splits

    @property
    def group_size(self) -> int:
        return len(self.layer_group)

    @property
    def n_groups(self) -> int:
        assert self.n_layers % self.group_size == 0, (self.n_layers, self.layer_group)
        return self.n_layers // self.group_size

    def n_params(self) -> int:
        """Approximate parameter count (embeddings included once if tied)."""
        d, f, L = self.d_model, self.d_ff, self.n_layers
        attn = d * self.n_heads * self.head_dim * 2 \
            + d * self.n_kv_heads * self.head_dim * 2
        mlp = d * f * (3 if self.gated_mlp else 2)
        per_layer = 0
        if self.family in ("dense", "moe", "encdec"):
            per_layer += attn
        if self.moe is not None:
            per_layer += d * self.moe.n_experts \
                + self.moe.n_experts * d * f * (3 if self.gated_mlp else 2)
            if self.moe.dense_residual:
                per_layer += mlp
        elif self.family in ("dense", "encdec"):
            per_layer += mlp
        if self.ssm is not None:
            s = self.ssm
            per_layer_ssm = d * (2 * s.d_inner + 2 * s.d_state + s.n_heads) \
                + s.d_inner * d
            if self.family == "hybrid":
                n_ssm = L
                shared = attn + mlp + 2 * d * d
                return n_ssm * per_layer_ssm + shared + self.vocab_size * d
            return L * per_layer_ssm + self.vocab_size * d
        total = L * per_layer + self.vocab_size * d
        if self.family == "encdec":
            total += self.encoder_layers * (attn + mlp) + self.pos_table_len * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        return total

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.n_params()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        dense = self.n_params() - L * self.moe.n_experts * d * f \
            * (3 if self.gated_mlp else 2)
        active_moe = L * self.moe.top_k * d * f * (3 if self.gated_mlp else 2)
        return dense + active_moe

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        g = self.group_size
        ssm = None
        if self.ssm is not None:
            ssm = SSMSpec(d_model=64, d_state=16, d_conv=4, expand=2,
                          head_dim=16, chunk=16)
        moe = None
        if self.moe is not None:
            moe = MoESpec(n_experts=4, top_k=min(2, self.moe.top_k),
                          capacity_factor=2.0,
                          dense_residual=self.moe.dense_residual)
        return dataclasses.replace(
            self,
            n_layers=2 * g if self.hybrid_period is None else 2 * (self.hybrid_period),
            d_model=64, n_heads=4, n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=16, d_ff=128, vocab_size=512,
            window=32 if self.window else None,
            moe=moe, ssm=ssm,
            hybrid_period=self.hybrid_period,
            encoder_layers=2 if self.encoder_layers else 0,
            pos_table_len=128 if self.pos_table_len else 0,
            q_block=32, k_block=32, ce_chunk=32,
            param_dtype="float32", compute_dtype="float32",
            remat="none", microbatches=1,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode

    def reduced(self) -> "ShapeConfig":
        return dataclasses.replace(
            self, seq_len=min(self.seq_len, 64),
            global_batch=min(self.global_batch, 2))


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

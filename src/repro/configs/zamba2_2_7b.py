"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention blocks (arXiv:2411.15242).

Shared transformer block (weight-tied) applied after every 6 SSM layers on
proj([hidden ; embedding]); per-application LoRA deltas of the released model
are simplified away (DESIGN.md §Arch-applicability).
"""
from .base import ModelConfig
from ..models.ssm import SSMSpec

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    ssm=SSMSpec(d_model=2560, d_state=64, d_conv=4, expand=2, head_dim=64,
                chunk=128),
    hybrid_period=6,
    rope_theta=10_000.0, tie_embeddings=True,
)

"""Config registry: --arch <id> -> ModelConfig; shapes; PDES experiment configs."""
from .base import ModelConfig, ShapeConfig, SHAPES  # noqa: F401

ARCH_IDS = [
    "internvl2-76b", "gemma2-2b", "qwen2.5-3b", "llama3.2-1b",
    "h2o-danube-3-4b", "whisper-base", "zamba2-2.7b", "mixtral-8x7b",
    "arctic-480b", "mamba2-130m",
]

_MODULES = {
    "internvl2-76b": "internvl2_76b",
    "gemma2-2b": "gemma2_2b",
    "qwen2.5-3b": "qwen2_5_3b",
    "llama3.2-1b": "llama3_2_1b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "whisper-base": "whisper_base",
    "zamba2-2.7b": "zamba2_2_7b",
    "mixtral-8x7b": "mixtral_8x7b",
    "arctic-480b": "arctic_480b",
    "mamba2-130m": "mamba2_130m",
}


def get_config(arch: str) -> ModelConfig:
    import importlib
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f".{_MODULES[arch]}", __package__)
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


# (arch, shape) cells skipped per the sub-quadratic rule; see DESIGN.md §6.
LONG_CONTEXT_SKIPS = {
    "internvl2-76b", "qwen2.5-3b", "llama3.2-1b", "arctic-480b",
    "whisper-base",
}


def cell_is_runnable(arch: str, shape: str) -> bool:
    if shape == "long_500k" and arch in LONG_CONTEXT_SKIPS:
        return False
    return True

"""h2o-danube-3-4b [dense]: llama+mistral mix with SWA (arXiv:2401.16818)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8, head_dim=120,
    d_ff=10240, vocab_size=32000,
    window=4096, layer_group=("local",),
    rope_theta=10_000.0, tie_embeddings=False,
)

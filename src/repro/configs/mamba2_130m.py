"""mamba2-130m [ssm]: SSD state-space duality, attention-free (arXiv:2405.21060)."""
from .base import ModelConfig
from ..models.ssm import SSMSpec

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    ssm=SSMSpec(d_model=768, d_state=128, d_conv=4, expand=2, head_dim=64,
                chunk=128),
    tie_embeddings=True,
)

"""arctic-480b [moe]: 128 experts top-2 + parallel dense residual
(hf:Snowflake/snowflake-arctic-base).

param_dtype/optimizer state run in bf16: fp32 m/v for 480B params would
exceed the 256x16 GB single-pod HBM budget (DESIGN.md §Arch-applicability).
"""
from .base import ModelConfig
from ..models.moe import MoESpec

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab_size=32000,
    moe=MoESpec(n_experts=128, top_k=2, capacity_factor=1.25,
                dense_residual=True),
    rope_theta=10_000.0, tie_embeddings=False,
    param_dtype="bfloat16",
)

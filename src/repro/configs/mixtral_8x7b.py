"""mixtral-8x7b [moe]: 8 experts top-2 + SWA (arXiv:2401.04088)."""
from .base import ModelConfig
from ..models.moe import MoESpec

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    moe=MoESpec(n_experts=8, top_k=2, capacity_factor=1.25),
    window=4096, layer_group=("local",),
    rope_theta=1_000_000.0, tie_embeddings=False,
)

"""whisper-base [audio]: enc-dec; conv frontend STUBBED (frame embeddings in).

pos_table_len is sized for the assigned decode_32k stress shape (the released
model caps at 448 target positions; we scale the learned table, noted in
DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, encoder_layers=6,
    d_model=512, n_heads=8, n_kv_heads=8, head_dim=64,
    d_ff=2048, vocab_size=51865,
    act="gelu", gated_mlp=False, norm="layernorm", qkv_bias=True,
    rope_theta=0.0, pos_table_len=32768,
    input_mode="embeddings", tie_embeddings=True,
    q_block=1024, k_block=2048,   # §Perf W2: flash carry traffic ∝ 1/k_block
)

"""repro.service — batched sweep serving: many users, shared device passes.

The ROADMAP's production layer: a request/response subsystem that accepts
``WindowSweep`` specs from many requesters and multiplexes them into shared
engine passes, packing each request's (trial, Δ) rows onto one ensemble/mesh
batch exactly the way ``PDESEngine.init_sweep`` packs a single spec's Δ
grid.  The contract is bit-identity: every response row equals a direct
``run_window_sweep`` of that request's spec (tests/test_service.py).

Modules:
  ``api``          request/response core (``SweepService.submit``/``drain``),
                   streaming emission, capped-backoff engine retries
  ``scheduler``    compatibility keying, Δ-grid union packing, admission
                   control + Eq. (3) requester fairness + per-round quotas
  ``state_cache``  row-granular LRU of burned-in states, persistable
                   across processes (``save``/``load``)
  ``wire``         versioned JSON schema (v2: structured ``error``
                   responses) + lazy, per-line-fault-tolerant JSONL intake
  ``daemon``       long-running watch-directory serve loop (SIGTERM-clean)

Run ``python -m repro.service queue.jsonl`` to drain a JSONL request queue
end-to-end, or ``python -m repro.service serve --intake DIR`` for the
daemon (see ``__main__``).

Attribute access is lazy (PEP 562) so the CLI can configure ``XLA_FLAGS``
(``--fake-devices``) before anything imports JAX.
"""
from __future__ import annotations

_EXPORTS = {
    "SweepService": "api", "SweepRequest": "api", "SweepResponse": "api",
    "ServiceStats": "api", "canonicalize_spec": "api",
    "spec_fingerprint": "api",
    "BatchScheduler": "scheduler", "CompatKey": "scheduler",
    "GridJob": "scheduler", "PackedPass": "scheduler",
    "window_admission": "scheduler",
    "StateCache": "state_cache", "CACHE_FORMAT_VERSION": "state_cache",
    "SCHEMA_VERSION": "wire", "SUPPORTED_VERSIONS": "wire",
    "encode_request": "wire", "decode_request": "wire",
    "encode_response": "wire", "decode_response": "wire",
    "encode_error": "wire", "read_queue": "wire", "serve_queue": "wire",
    "WireError": "wire", "QueueItem": "wire",
    "DaemonConfig": "daemon", "serve_daemon": "daemon",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name: str):
    if name in _EXPORTS:
        import importlib
        mod = importlib.import_module(f".{_EXPORTS[name]}", __name__)
        return getattr(mod, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_EXPORTS))

"""``python -m repro.service`` — drain a queue, or run the serve daemon.

One-shot drain (the original mode)::

    python -m repro.service queue.jsonl [--out responses.jsonl]
        [--fake-devices N] [--mesh data=2,model=4] [--state-cache PATH]
        [--max-batch-rows N] [--max-wait-rounds N] [--fairness-rows N]
        [--quota-rows N] [--engine-retries N]
        [--metrics-dir DIR] [--trace FILE]

Each input line is a wire-schema request (see ``wire.py``); one response
line is written per input line, in queue order, streamed/flushed as each
completes.  Malformed lines get structured ``error`` responses instead of
aborting the drain.

Daemon mode::

    python -m repro.service serve --intake DIR [--out responses.jsonl]
        [--state-cache PATH] [--poll 0.25] [--idle-exit-rounds N]
        [--max-line-bytes N] [...same service knobs as above...]
        [--metrics-dir DIR] [--trace FILE]

Both modes accept ``--metrics-dir`` (atomic ``metrics.json`` +
``metrics.prom`` snapshots of the live registry: paper observables per
pass, service health, daemon phase timing) and ``--trace`` (Chrome-trace
JSON, one span per coalesced pass annotated with its CompatKey, row
counts, and cache provenance).  Render/validate either with
``python -m repro.obs summarize [--check]``.  Telemetry is strictly
off-path: responses are bit-identical with or without these flags.

Watches DIR for ``*.jsonl`` request files, serves continuously (arrivals
batched per scheduler round, per-requester quotas on top of the Eq. (3)
fairness window), renames processed files to ``*.done``, and appends
responses as they complete.  SIGTERM/SIGINT flush in-flight work and exit
cleanly; see ``daemon.py``.

``--fake-devices`` forces an N-device CPU platform (for
``backend="sharded"`` requests on a development host) and therefore must
be applied *before* JAX loads — which is why this module parses arguments
before importing the service and the package ``__init__`` is lazy.  If JAX
is somehow already imported the flag fails loudly instead of silently
no-opping.
"""
from __future__ import annotations

import argparse
import os
import sys


def _parse_mesh(text: str) -> list[tuple[str, int]]:
    out = []
    for part in text.split(","):
        name, _, size = part.partition("=")
        if not size:
            raise argparse.ArgumentTypeError(
                f"mesh axis {part!r} is not name=size")
        out.append((name.strip(), int(size)))
    return out


def _add_service_args(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--fake-devices", type=int, default=0, metavar="N",
                    help="force an N-device CPU platform (sharded requests "
                         "on a dev host); must run before JAX imports")
    ap.add_argument("--mesh", type=_parse_mesh, default=None,
                    metavar="data=2,model=4",
                    help="device mesh for backend='sharded' requests")
    ap.add_argument("--state-cache", default=None, metavar="PATH",
                    help="persist/restore the burned-state cache here "
                         "(npz; survives process restarts)")
    ap.add_argument("--max-batch-rows", type=int, default=4096)
    ap.add_argument("--max-wait-rounds", type=int, default=0)
    ap.add_argument("--fairness-rows", type=float, default=float("inf"),
                    help="Eq. (3) window over cumulative served rows "
                         "(laggard = GVT); inf disables")
    ap.add_argument("--quota-rows", type=float, default=float("inf"),
                    help="per-requester row budget per scheduling round "
                         "(tenant-layer Delta); inf disables")
    ap.add_argument("--engine-retries", type=int, default=0,
                    help="capped-backoff retries per failing device pass "
                         "before the per-request error response")
    ap.add_argument("--state-cache-rows", type=int, default=65536,
                    help="LRU bound of the burned-state cache, in rows")
    ap.add_argument("--metrics-dir", default=None, metavar="DIR",
                    help="write atomic metrics.json/metrics.prom snapshots "
                         "here (live paper observables + service health; "
                         "see repro.obs)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="record a Chrome-trace/Perfetto JSON here (one "
                         "span per coalesced pass, CompatKey-annotated)")


def _apply_fake_devices(args) -> int:
    """Set XLA_FLAGS for --fake-devices; error loudly if JAX beat us."""
    if not args.fake_devices:
        return 0
    if "jax" in sys.modules:
        print("error: --fake-devices must take effect before JAX is "
              "imported, but 'jax' is already in sys.modules — the flag "
              "would silently do nothing.  Run this CLI in a fresh "
              "process, or export XLA_FLAGS="
              f"--xla_force_host_platform_device_count={args.fake_devices} "
              "before starting Python.", file=sys.stderr)
        return 2
    flag = f"--xla_force_host_platform_device_count={args.fake_devices}"
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " " + flag).strip()
    return 0


def _build_mesh(args):
    """The device mesh for --mesh, or an error-message string."""
    if not args.mesh:
        return None
    import jax
    import numpy as np
    from jax.sharding import Mesh
    names = [n for n, _ in args.mesh]
    sizes = [s for _, s in args.mesh]
    n_dev = int(np.prod(sizes))
    if len(jax.devices()) < n_dev:
        return f"mesh needs {n_dev} devices, have {len(jax.devices())}"
    devs = np.asarray(jax.devices()[:n_dev]).reshape(sizes)
    return Mesh(devs, tuple(names))


def _build_telemetry(args):
    """A ``repro.obs.Telemetry`` bundle when either flag asks for one."""
    if not (args.metrics_dir or args.trace):
        return None
    from ..obs import Telemetry, TraceRecorder
    return Telemetry(tracer=TraceRecorder() if args.trace else None)


def _build_service(args, telemetry=None):
    from .api import SweepService
    mesh = _build_mesh(args)
    if isinstance(mesh, str):
        print(f"error: {mesh}", file=sys.stderr)
        return None
    return SweepService(mesh=mesh,
                        max_batch_rows=args.max_batch_rows,
                        max_wait_rounds=args.max_wait_rounds,
                        fairness_rows=args.fairness_rows,
                        quota_rows=args.quota_rows,
                        engine_retries=args.engine_retries,
                        state_cache_rows=args.state_cache_rows,
                        telemetry=telemetry)


def _summary(stats) -> str:
    return (f"served {stats.n_requests} request(s): "
            f"{stats.n_deduped} deduped, {stats.n_errors} error(s), "
            f"{stats.n_passes} coalesced pass(es), "
            f"{stats.rows_computed} rows computed, "
            f"{stats.rows_from_state_cache} rows from state cache, "
            f"{stats.engine_row_steps} engine row-steps; state cache "
            f"{stats.state_cache_hits} hit(s) / "
            f"{stats.state_cache_misses} miss(es) / "
            f"{stats.state_cache_evictions} eviction(s)")


def _main_drain(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="Drain a JSONL window-sweep request queue "
                    "(or: `serve` for daemon mode).")
    ap.add_argument("queue", help="JSONL file of wire-schema requests")
    ap.add_argument("--out", default=None,
                    help="responses JSONL path (default: stdout)")
    _add_service_args(ap)
    args = ap.parse_args(argv)

    if _apply_fake_devices(args):
        return 2

    # deferred so --fake-devices lands before the first JAX import
    from .wire import serve_queue

    tel = _build_telemetry(args)
    service = _build_service(args, telemetry=tel)
    if service is None:
        return 2
    if args.state_cache and os.path.exists(args.state_cache):
        service.state_cache.load(args.state_cache)
    if tel is not None and tel.tracer is not None:
        from ..obs import set_tracer
        set_tracer(tel.tracer)     # library-level spans join the trace
    if args.out:
        with open(args.out, "w") as fh:
            stats = serve_queue(args.queue, fh, service=service)
    else:
        stats = serve_queue(args.queue, sys.stdout, service=service)
    if args.state_cache and service.state_cache.dirty:
        service.state_cache.save(args.state_cache)
    if tel is not None:
        if args.metrics_dir:
            from ..obs import write_snapshot
            write_snapshot(tel.registry, args.metrics_dir)
        if args.trace:
            tel.tracer.save(args.trace)
    print(_summary(stats), file=sys.stderr)
    return 0


def _main_serve(argv) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service serve",
        description="Long-running watch-directory sweep-service daemon.")
    ap.add_argument("--intake", required=True, metavar="DIR",
                    help="directory watched for *.jsonl request files "
                         "(processed files are renamed to *.done)")
    ap.add_argument("--out", default="responses.jsonl",
                    help="responses JSONL, append mode (default: "
                         "responses.jsonl)")
    ap.add_argument("--poll", type=float, default=0.25, metavar="SECONDS",
                    help="idle poll interval")
    ap.add_argument("--idle-exit-rounds", type=int, default=None,
                    metavar="N",
                    help="exit cleanly after N consecutive idle rounds "
                         "(default: run until SIGTERM)")
    ap.add_argument("--max-rounds", type=int, default=None, metavar="N",
                    help="hard cap on serve rounds (tests/smoke)")
    ap.add_argument("--max-line-bytes", type=int, default=None, metavar="N",
                    help="intake cap per request line (default 1 MiB); "
                         "longer lines get structured oversize errors")
    ap.add_argument("--max-files-per-round", type=int, default=None,
                    metavar="N",
                    help="intake meter: at most N request files per round")
    ap.add_argument("--crash-after-passes", type=int, default=None,
                    help=argparse.SUPPRESS)   # fault injection (tests)
    _add_service_args(ap)
    args = ap.parse_args(argv)

    if _apply_fake_devices(args):
        return 2

    from .daemon import DaemonConfig, serve_daemon
    from .wire import DEFAULT_MAX_LINE_BYTES

    tel = _build_telemetry(args)
    service = _build_service(args, telemetry=tel)
    if service is None:
        return 2
    if tel is not None and tel.tracer is not None:
        from ..obs import set_tracer
        set_tracer(tel.tracer)     # library-level spans join the trace
    cfg = DaemonConfig(
        intake_dir=args.intake, out_path=args.out,
        state_cache_path=args.state_cache,
        poll_interval_s=args.poll,
        max_line_bytes=(DEFAULT_MAX_LINE_BYTES if args.max_line_bytes is None
                        else args.max_line_bytes),
        max_files_per_round=args.max_files_per_round,
        idle_exit_rounds=args.idle_exit_rounds,
        max_rounds=args.max_rounds,
        crash_after_passes=args.crash_after_passes,
        metrics_dir=args.metrics_dir,
        trace_path=args.trace)
    stats = serve_daemon(cfg, service=service)
    print(_summary(stats), file=sys.stderr)
    return 0


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "serve":
        return _main_serve(argv[1:])
    return _main_drain(argv)


if __name__ == "__main__":
    sys.exit(main())

"""``python -m repro.service`` — drain a JSONL sweep-request queue.

Usage::

    python -m repro.service queue.jsonl [--out responses.jsonl]
        [--fake-devices N] [--mesh data=2,model=4]
        [--max-batch-rows N] [--max-wait-rounds N] [--fairness-rows N]

Each input line is a wire-schema request (see ``wire.py``); one response
line is written per request, in submission order.  ``--fake-devices``
forces an N-device CPU platform (for ``backend="sharded"`` requests on a
development host) and therefore must be applied *before* JAX loads — which
is why this module parses arguments before importing the service and the
package ``__init__`` is lazy.
"""
from __future__ import annotations

import argparse
import os
import sys


def _parse_mesh(text: str) -> list[tuple[str, int]]:
    out = []
    for part in text.split(","):
        name, _, size = part.partition("=")
        if not size:
            raise argparse.ArgumentTypeError(
                f"mesh axis {part!r} is not name=size")
        out.append((name.strip(), int(size)))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.service",
        description="Drain a JSONL window-sweep request queue.")
    ap.add_argument("queue", help="JSONL file of wire-schema requests")
    ap.add_argument("--out", default=None,
                    help="responses JSONL path (default: stdout)")
    ap.add_argument("--fake-devices", type=int, default=0, metavar="N",
                    help="force an N-device CPU platform (sharded requests "
                         "on a dev host); set before JAX imports")
    ap.add_argument("--mesh", type=_parse_mesh, default=None,
                    metavar="data=2,model=4",
                    help="device mesh for backend='sharded' requests")
    ap.add_argument("--max-batch-rows", type=int, default=4096)
    ap.add_argument("--max-wait-rounds", type=int, default=0)
    ap.add_argument("--fairness-rows", type=float, default=float("inf"))
    args = ap.parse_args(argv)

    if args.fake_devices:
        flag = f"--xla_force_host_platform_device_count={args.fake_devices}"
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") + " " + flag).strip()

    # deferred so --fake-devices lands before the first JAX import
    from .api import SweepService
    from .wire import serve_queue

    mesh = None
    if args.mesh:
        import jax
        import numpy as np
        from jax.sharding import Mesh
        names = [n for n, _ in args.mesh]
        sizes = [s for _, s in args.mesh]
        n_dev = int(np.prod(sizes))
        if len(jax.devices()) < n_dev:
            print(f"error: mesh needs {n_dev} devices, have "
                  f"{len(jax.devices())}", file=sys.stderr)
            return 2
        devs = np.asarray(jax.devices()[:n_dev]).reshape(sizes)
        mesh = Mesh(devs, tuple(names))

    service = SweepService(mesh=mesh,
                           max_batch_rows=args.max_batch_rows,
                           max_wait_rounds=args.max_wait_rounds,
                           fairness_rows=args.fairness_rows)
    if args.out:
        with open(args.out, "w") as fh:
            stats = serve_queue(args.queue, fh, service=service)
    else:
        stats = serve_queue(args.queue, sys.stdout, service=service)
    print(f"served {stats.n_requests} request(s): "
          f"{stats.n_deduped} deduped, {stats.n_passes} coalesced pass(es), "
          f"{stats.rows_computed} rows computed, "
          f"{stats.rows_from_state_cache} rows from state cache, "
          f"{stats.engine_row_steps} engine row-steps", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())

"""Batching scheduler: coalesce compatible sweep requests into shared passes.

Pure packing logic (numpy-light, no JAX imports): the executable half of the
service lives in ``api.py``.  The scheduler's job is deciding *which rows
run together*:

* **Compatibility keying** — two grid-point jobs may share a device pass iff
  they agree on everything that determines a row's trajectory and the pass
  shape: ``(L, N_V, backend, window, k_fuse, rd_mode, border_both, seed,
  burn, n_steps)`` (:class:`CompatKey`).  ``replicas``/``deltas``/
  ``steady_frac`` deliberately stay *out* of the key: they only shape which
  rows a request wants and how its slice is reduced.
* **Δ-grid union** — a row is a ``(trial_index, Δ)`` coordinate; the pass
  operand is the first-seen-ordered union of every job's rows, and each job
  keeps the column indices of *its* rows (:class:`PackedPass`).  Rows that
  two requests share (same trial block, same Δ) are computed once.
* **Admission control** — groups are released when forced, when they have
  waited ``max_wait_rounds`` scheduling rounds, or when they already fill a
  pass; released jobs are packed into passes of at most ``max_batch_rows``
  union rows (job granularity — an oversized job gets its own pass).
* **Fairness** — requesters are throttled by the paper's own moving-window
  rule, Eq. (3), reused verbatim: a requester's *served row count* plays the
  local virtual time τ, the minimum over requesters plays the GVT, and
  ``fairness_rows`` plays Δ — :func:`window_admission` decides who may enter
  the next pass.  The same helper gates DP workers in
  ``repro.distributed.delta_sync`` and decode lanes in ``repro.serve``.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

__all__ = ["CompatKey", "GridJob", "PackedPass", "BatchScheduler",
           "window_admission"]


def window_admission(tau, delta, gvt):
    """The paper's Eq. (3) moving-window rule: ``tau <= delta + gvt``.

    Elementwise over arrays (returns a bool array) and exact on scalars
    (returns a bool).  This single predicate is the Δ-window constraint
    everywhere it appears in this tree: the PDES window rule it names, the
    bounded-staleness gate of ``repro.distributed.delta_sync``, the decode
    lanes of ``repro.serve``, and requester fairness in this scheduler.
    """
    out = np.asarray(tau) <= delta + gvt
    return bool(out) if out.ndim == 0 else out


@dataclasses.dataclass(frozen=True)
class CompatKey:
    """Everything two jobs must agree on to share one device pass.

    The first eight fields pin a row's *trajectory* (the counter stream and
    update schedule); ``burn``/``n_steps`` pin the pass shape (one scalar
    step counter per pass).  ``stream_key`` drops ``n_steps`` — it is the
    burned-state cache key prefix (a burned state is reusable under any
    later measurement length).
    """

    L: int
    n_v: int
    backend: str
    window: str
    k_fuse: int
    rd_mode: bool
    border_both: bool
    seed: int
    burn: int
    n_steps: int

    @property
    def stream_key(self) -> tuple:
        return (self.L, self.n_v, self.backend, self.window, self.k_fuse,
                self.rd_mode, self.border_both, self.seed, self.burn)


@dataclasses.dataclass(frozen=True)
class GridJob:
    """One (request, L, N_V) grid point: the scheduling unit.

    ``rows`` is the job's (trial_index, Δ) coordinates in request order —
    window-major, replica-inner, exactly the layout ``run_window_sweep``
    assigns (``trial = grid_base + w * replicas + r``) — so slicing the
    job's columns out of a coalesced pass reproduces the standalone rows.
    """

    fp: str                  # canonical-spec fingerprint this job serves
    requester: str
    seq: int                 # submission order (fairness tiebreak)
    key: CompatKey
    rows: tuple              # ((trial, delta), ...) request-ordered
    deltas: tuple            # the job's Δ grid (n_windows values)
    replicas: int
    steady_frac: float


@dataclasses.dataclass(frozen=True)
class PackedPass:
    """One coalesced device pass: union rows + per-job column slices."""

    key: CompatKey
    jobs: tuple              # GridJobs served by this pass
    rows: tuple              # union (trial, delta) rows, first-seen order
    cols: tuple              # per-job tuple of column indices into ``rows``

    @property
    def n_rows(self) -> int:
        return len(self.rows)


def _pack(key: CompatKey, jobs, max_rows: int) -> list:
    """Greedy job-granular packing into passes of <= max_rows union rows."""
    passes, cur, seen = [], [], {}

    def flush():
        if cur:
            rows = tuple(seen)
            index = {r: i for i, r in enumerate(rows)}
            cols = tuple(tuple(index[r] for r in j.rows) for j in cur)
            passes.append(PackedPass(key=key, jobs=tuple(cur), rows=rows,
                                     cols=cols))
            cur.clear()
            seen.clear()

    for job in jobs:
        fresh = [r for r in job.rows if r not in seen]
        if cur and len(seen) + len(fresh) > max_rows:
            flush()
            fresh = job.rows
        for r in fresh:
            seen[r] = None
        cur.append(job)
    flush()
    return passes


class BatchScheduler:
    """Admission control + fairness + packing over pending :class:`GridJob`s.

    Args:
      max_batch_rows: union-row cap per coalesced pass.
      max_wait_rounds: how many ``take()`` rounds an under-filled compat
        group may defer, accumulating co-batchable requests, before it is
        released anyway (0 = release immediately).
      fairness_rows: the Δ of the requester-fairness window (Eq. (3) over
        served row counts); ``inf`` disables throttling.
      quota_rows: per-requester row budget *per scheduling round* — the
        tenant-layer Δ on top of the fairness window.  A requester whose
        admitted rows this round would exceed the quota has their remaining
        jobs deferred to later rounds (never rejected), so a flooding
        tenant is metered to ``quota_rows`` rows/round while laggards keep
        the fairness window's priority.  A single job larger than the quota
        is still released when it is the requester's first job of the round
        (quotas bound throughput, they must not deadlock a request).
        ``inf`` disables metering.
    """

    def __init__(self, *, max_batch_rows: int = 4096,
                 max_wait_rounds: int = 0,
                 fairness_rows: float = math.inf,
                 quota_rows: float = math.inf):
        if max_batch_rows < 1:
            raise ValueError("max_batch_rows must be >= 1")
        if max_wait_rounds < 0:
            raise ValueError("max_wait_rounds must be >= 0")
        if quota_rows < 1:
            raise ValueError("quota_rows must be >= 1")
        self.max_batch_rows = max_batch_rows
        self.max_wait_rounds = max_wait_rounds
        self.fairness_rows = fairness_rows
        self.quota_rows = quota_rows
        # lifetime throttle ledgers (jobs deferred, not rejected) — read by
        # the telemetry layer as repro_service_{fairness,quota}_throttles
        self.fairness_deferrals = 0
        self.quota_deferrals = 0
        self._pending: list[GridJob] = []
        self._waited: dict[CompatKey, int] = {}

    # -- queue state -------------------------------------------------------

    def enqueue(self, job: GridJob) -> None:
        self._pending.append(job)

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    def pending_union_rows(self, key: CompatKey) -> int:
        rows = {r for j in self._pending if j.key == key for r in j.rows}
        return len(rows)

    @property
    def pending_requesters(self) -> set:
        """Requesters with at least one pending job (the active tenants)."""
        return {j.requester for j in self._pending}

    def drop_fps(self, fps) -> int:
        """Discard pending jobs serving any of the given fingerprints.

        Used when a fingerprint fails permanently: its sibling grid-point
        jobs can no longer contribute to a response.  Returns the number of
        jobs dropped.
        """
        fps = set(fps)
        before = len(self._pending)
        self._pending = [j for j in self._pending if j.fp not in fps]
        return before - len(self._pending)

    # -- one scheduling round ---------------------------------------------

    def _admitted(self, job: GridJob, served: dict) -> bool:
        if not served or math.isinf(self.fairness_rows):
            return True
        gvt = min(served.values())
        return window_admission(served.get(job.requester, 0),
                                self.fairness_rows, gvt)

    def take(self, served: dict | None = None,
             force: bool = False) -> list[PackedPass]:
        """Release ready compat groups and pack them into passes.

        ``served`` maps requester -> rows served so far (the fairness τ).
        Non-forced rounds hold back (a) under-filled groups that have not
        yet waited ``max_wait_rounds`` and (b) jobs whose requester the
        fairness window blocks; ``force=True`` releases everything
        (``drain`` semantics — every request is eventually served, the
        window only shapes the order).
        """
        served = served or {}
        by_key: dict[CompatKey, list[GridJob]] = {}
        for j in self._pending:
            by_key.setdefault(j.key, []).append(j)

        passes, released = [], []
        round_rows: dict[str, int] = {}    # per-round quota ledger
        for key, jobs in by_key.items():
            if not force:
                admitted = [j for j in jobs if self._admitted(j, served)]
                self.fairness_deferrals += len(jobs) - len(admitted)
                waited = self._waited.get(key, 0)
                full = self.pending_union_rows(key) >= self.max_batch_rows
                if not admitted or (waited < self.max_wait_rounds
                                    and not full):
                    self._waited[key] = waited + 1
                    continue
                jobs = admitted
            # fairness orders the pack: least-served requesters first
            jobs = sorted(jobs, key=lambda j: (served.get(j.requester, 0),
                                               j.seq))
            if not force and not math.isinf(self.quota_rows):
                kept = [j for j in jobs if self._within_quota(j, round_rows)]
                self.quota_deferrals += len(jobs) - len(kept)
                jobs = kept
                if not jobs:
                    continue           # whole group deferred by quota
            passes.extend(_pack(key, jobs, self.max_batch_rows))
            released.extend(jobs)
            self._waited.pop(key, None)
        taken = set(id(j) for j in released)
        self._pending = [j for j in self._pending if id(j) not in taken]
        return passes

    def _within_quota(self, job: GridJob, round_rows: dict) -> bool:
        used = round_rows.get(job.requester, 0)
        if used and used + len(job.rows) > self.quota_rows:
            return False
        round_rows[job.requester] = used + len(job.rows)
        return True

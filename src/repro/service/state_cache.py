"""Burned-in-state cache: skip re-burning rows the service has seen before.

The burn-in phase dominates a sweep's cost (hundreds to thousands of steps
against a few hundred measured), and it is *deterministic*: a row's burned
state is a pure function of ``(stream_key, trial, Δ)`` — the compat fields
that pin the trajectory (``CompatKey.stream_key``, which includes the burn
length) plus the row coordinate.  Because every ensemble row is an
independent ring, rows can be burned in any grouping and reassembled
freely, so the cache works at *row* granularity: a later pass burns only
its cache-missing rows in a sub-pass and splices the rest in, bit-identical
to burning everything from scratch (asserted in tests/test_service.py).

Reuse shows up across requests (two users sweeping overlapping Δ grids) and
across adaptive-refinement rounds (``experiments.optimal_window.
refine_optimal_window`` re-measuring its bracket at a longer ``n_steps``).

LRU-bounded in *rows* (one row holds an ``(L,)`` float32 ring + the Kahan
offset pair), so the bound tracks actual memory: ``max_rows * (L + 2) * 4``
bytes per ring size.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

__all__ = ["StateCache"]


class StateCache:
    """Row-granular LRU of burned-in states.

    Keys are ``stream_key + (trial, delta)`` tuples (hashable); values are
    ``(tau_row (L,), offset, offset_comp)`` float32 numpy copies — host
    memory, detached from any device buffer.
    """

    def __init__(self, max_rows: int = 65536):
        if max_rows < 1:
            raise ValueError("max_rows must be >= 1")
        self.max_rows = max_rows
        self._rows: OrderedDict[tuple, tuple] = OrderedDict()
        self.hits = 0
        self.misses = 0

    def __len__(self) -> int:
        return len(self._rows)

    def get(self, key: tuple):
        """The cached ``(tau_row, offset, comp)`` or None; refreshes LRU."""
        try:
            self._rows.move_to_end(key)
        except KeyError:
            self.misses += 1
            return None
        self.hits += 1
        return self._rows[key]

    def put(self, key: tuple, tau_row, offset, comp) -> None:
        self._rows[key] = (np.array(tau_row, np.float32, copy=True),
                           np.float32(offset), np.float32(comp))
        self._rows.move_to_end(key)
        while len(self._rows) > self.max_rows:
            self._rows.popitem(last=False)

    def put_batch(self, keys, tau, offset, comp) -> None:
        """Cache rows ``i -> keys[i]`` of a burned batch state."""
        tau = np.asarray(tau)
        offset = np.asarray(offset)
        comp = np.asarray(comp)
        for i, key in enumerate(keys):
            self.put(key, tau[i], offset[i], comp[i])

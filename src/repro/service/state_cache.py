"""Burned-in-state cache: skip re-burning rows the service has seen before.

The burn-in phase dominates a sweep's cost (hundreds to thousands of steps
against a few hundred measured), and it is *deterministic*: a row's burned
state is a pure function of ``(stream_key, trial, Δ)`` — the compat fields
that pin the trajectory (``CompatKey.stream_key``, which includes the burn
length) plus the row coordinate.  Because every ensemble row is an
independent ring, rows can be burned in any grouping and reassembled
freely, so the cache works at *row* granularity: a later pass burns only
its cache-missing rows in a sub-pass and splices the rest in, bit-identical
to burning everything from scratch (asserted in tests/test_service.py).

Reuse shows up across requests (two users sweeping overlapping Δ grids),
across adaptive-refinement rounds (``experiments.optimal_window.
refine_optimal_window`` re-measuring its bracket at a longer ``n_steps``)
— and, via :meth:`StateCache.save`/:meth:`StateCache.load`, across
*processes*: the daemon persists the cache each round, so a restarted
service resumes from the burned rows the previous incarnation paid for
(with responses bit-identical to an uninterrupted run, because the cached
state is exactly what the uninterrupted pass would have burned).

LRU-bounded in *rows* (one row holds an ``(L,)`` float32 ring + the Kahan
offset pair), so the bound tracks actual memory: ``max_rows * (L + 2) * 4``
bytes per ring size.  ``hits``/``misses``/``evictions`` counters make
cache thrash under ``max_rows`` pressure observable (all three are
surfaced in ``ServiceStats`` and the CLI summary line).
"""
from __future__ import annotations

import io
import json
import os
from collections import OrderedDict

import numpy as np

__all__ = ["StateCache", "CACHE_FORMAT_VERSION"]

#: on-disk format version of :meth:`StateCache.save`; bumped on layout
#: changes.  ``load`` refuses (returns 0, cache untouched) on mismatch.
CACHE_FORMAT_VERSION = 1


class StateCache:
    """Row-granular LRU of burned-in states.

    Keys are ``stream_key + (trial, delta)`` tuples (hashable); values are
    ``(tau_row (L,), offset, offset_comp)`` float32 numpy copies — host
    memory, detached from any device buffer.
    """

    def __init__(self, max_rows: int = 65536):
        if max_rows < 1:
            raise ValueError("max_rows must be >= 1")
        self.max_rows = max_rows
        self._rows: OrderedDict[tuple, tuple] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.saves = 0              # successful save() calls
        self.loads = 0              # load() calls that restored >= 1 row
        self.restored_rows = 0      # rows brought back across processes
        self.dirty = False          # rows added since the last save/load

    def __len__(self) -> int:
        return len(self._rows)

    def get(self, key: tuple):
        """The cached ``(tau_row, offset, comp)`` or None; refreshes LRU."""
        try:
            self._rows.move_to_end(key)
        except KeyError:
            self.misses += 1
            return None
        self.hits += 1
        return self._rows[key]

    def put(self, key: tuple, tau_row, offset, comp) -> None:
        self._rows[key] = (np.array(tau_row, np.float32, copy=True),
                           np.float32(offset), np.float32(comp))
        self._rows.move_to_end(key)
        self.dirty = True
        while len(self._rows) > self.max_rows:
            self._rows.popitem(last=False)
            self.evictions += 1

    def put_batch(self, keys, tau, offset, comp) -> None:
        """Cache rows ``i -> keys[i]`` of a burned batch state."""
        tau = np.asarray(tau)
        offset = np.asarray(offset)
        comp = np.asarray(comp)
        for i, key in enumerate(keys):
            self.put(key, tau[i], offset[i], comp[i])

    # -- cross-process persistence ----------------------------------------

    def save(self, path) -> int:
        """Persist every cached row to ``path`` (npz + key manifest).

        Atomic (written to ``path + ".tmp"`` then renamed) and versioned.
        Rows are grouped by ring length (keys with different ``L`` coexist
        in one cache) and stored in LRU order, oldest first, so a reloaded
        cache evicts in the same order the live one would have.  Returns
        the number of rows written.

        Key components are JSON-serialized; ``Δ = inf`` round-trips via
        Python's ``Infinity`` literal extension, and every component type
        the service uses (str / int / float / bool) survives exactly.
        """
        groups: dict[int, list] = {}            # ring length -> [(key, val)]
        for key, val in self._rows.items():     # OrderedDict: LRU order
            groups.setdefault(int(val[0].shape[0]), []).append((key, val))
        manifest = {"format": CACHE_FORMAT_VERSION,
                    "groups": [{"L": L, "keys": [list(k) for k, _ in rows]}
                               for L, rows in groups.items()]}
        arrays = {"manifest": np.frombuffer(
            json.dumps(manifest).encode(), dtype=np.uint8)}
        for gi, (L, rows) in enumerate(groups.items()):
            arrays[f"tau_{gi}"] = np.stack([v[0] for _, v in rows])
            arrays[f"off_{gi}"] = np.asarray([v[1] for _, v in rows],
                                             np.float32)
            arrays[f"comp_{gi}"] = np.asarray([v[2] for _, v in rows],
                                              np.float32)
        tmp = f"{path}.tmp"
        buf = io.BytesIO()
        np.savez_compressed(buf, **arrays)
        with open(tmp, "wb") as fh:
            fh.write(buf.getvalue())
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, path)
        self.dirty = False
        self.saves += 1
        return len(self._rows)

    def load(self, path) -> int:
        """Restore rows saved by :meth:`save`; returns rows restored.

        Corruption-tolerant by contract: a missing file, truncated/garbage
        bytes, a bad manifest, mismatched array shapes, or a format-version
        mismatch all return 0 and leave the cache exactly as it was — a
        damaged cache file degrades to a cold start, never to a crash
        (restarting cleanly *is* the daemon's recovery path).  Restored
        rows keep their saved LRU order and count as neither hits nor
        misses; rows already in the cache keep their (fresher) live value.
        """
        try:
            with np.load(path) as npz:
                manifest = json.loads(bytes(npz["manifest"]).decode())
                if manifest.get("format") != CACHE_FORMAT_VERSION:
                    return 0
                restored = []
                for gi, group in enumerate(manifest["groups"]):
                    L = int(group["L"])
                    keys = [tuple(k) for k in group["keys"]]
                    tau = np.asarray(npz[f"tau_{gi}"], np.float32)
                    off = np.asarray(npz[f"off_{gi}"], np.float32)
                    comp = np.asarray(npz[f"comp_{gi}"], np.float32)
                    if tau.shape != (len(keys), L) or \
                            off.shape != (len(keys),) or \
                            comp.shape != (len(keys),):
                        return 0
                    restored.extend(
                        (k, (tau[i].copy(), off[i], comp[i]))
                        for i, k in enumerate(keys))
        except Exception:
            return 0
        # restored rows enter colder than any live row (live values are
        # fresher), keeping their saved LRU order among themselves
        merged: OrderedDict[tuple, tuple] = OrderedDict()
        n = 0
        for key, val in restored:
            if key not in self._rows:
                merged[key] = val
                n += 1
        for key, val in self._rows.items():
            merged[key] = val
        self._rows = merged
        while len(self._rows) > self.max_rows:
            self._rows.popitem(last=False)
            self.evictions += 1
        if n:
            self.loads += 1
            self.restored_rows += n
        return n

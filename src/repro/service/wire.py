"""Versioned JSON wire schema for the sweep service.

Request line (one JSON object per JSONL line)::

    {"version": 1, "requester": "alice", "spec": {...WindowSweep fields...}}

Response line::

    {"version": 1, "request_id": "...", "requester": "alice",
     "cached": false, "result": {"spec": {...}, "records": [...]}}

The ``spec``/``result`` payloads are exactly the canonical encodings of
``repro.experiments.sweep`` (``spec_to_dict`` / ``SweepResult.as_dict`` —
``inf`` spelled as the string ``"inf"``), so a response body is the same
document ``SweepResult.to_json`` writes, wrapped in routing metadata.
"""
from __future__ import annotations

import json

from ..experiments.sweep import (SweepResult, WindowSweep, spec_from_dict,
                                 spec_to_dict)
from .api import SweepRequest, SweepResponse

__all__ = ["SCHEMA_VERSION", "encode_request", "decode_request",
           "encode_response", "decode_response", "read_queue",
           "write_responses"]

SCHEMA_VERSION = 1


def _check_version(obj: dict, what: str) -> None:
    v = obj.get("version", SCHEMA_VERSION)
    if v != SCHEMA_VERSION:
        raise ValueError(f"unsupported {what} schema version {v!r} "
                         f"(this build speaks {SCHEMA_VERSION})")


def encode_request(spec: WindowSweep, requester: str = "anon") -> dict:
    return {"version": SCHEMA_VERSION, "requester": requester,
            "spec": spec_to_dict(spec)}


def decode_request(obj: dict) -> tuple[WindowSweep, str]:
    """(spec, requester) from a request object; validates the version."""
    _check_version(obj, "request")
    return spec_from_dict(obj["spec"]), str(obj.get("requester", "anon"))


def encode_response(resp: SweepResponse) -> dict:
    return {"version": SCHEMA_VERSION, "request_id": resp.request_id,
            "requester": resp.requester, "cached": resp.cached,
            "result": resp.result.as_dict()}


def decode_response(obj: dict) -> SweepResponse:
    _check_version(obj, "response")
    result = SweepResult.from_dict(obj["result"])
    return SweepResponse(request_id=str(obj["request_id"]),
                         requester=str(obj["requester"]),
                         spec=result.spec, result=result,
                         cached=bool(obj["cached"]))


def read_queue(path) -> list[tuple[WindowSweep, str]]:
    """Parse a JSONL queue file into (spec, requester) pairs."""
    out = []
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(decode_request(json.loads(line)))
    return out


def write_responses(responses, fh) -> None:
    """Write responses as JSONL to an open text stream."""
    for resp in responses:
        fh.write(json.dumps(encode_response(resp)) + "\n")


def serve_queue(queue_path, out_fh, *, service=None) -> "ServiceStats":
    """Drain a JSONL queue end-to-end; returns the service stats.

    The ``python -m repro.service`` entry point: builds a service (unless
    one is injected), submits every request line in file order, drains, and
    writes one response line per request.
    """
    from .api import ServiceStats, SweepService  # noqa: F401 (return type)
    if service is None:
        service = SweepService()
    for spec, requester in read_queue(queue_path):
        service.submit(spec, requester=requester)
    write_responses(service.drain(), out_fh)
    return service.stats

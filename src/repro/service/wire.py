"""Versioned JSON wire schema for the sweep service.

Request line (one JSON object per JSONL line)::

    {"version": 2, "requester": "alice", "spec": {...WindowSweep fields...}}

Response line (success)::

    {"version": 2, "request_id": "...", "requester": "alice",
     "cached": false, "result": {"spec": {...}, "records": [...]}}

Response line (failure — schema v2)::

    {"version": 2, "request_id": "line-7", "requester": "alice",
     "error": {"code": "parse", "message": "...", "lineno": 7}}

Schema v2 adds the optional ``"error"`` response field (a structured
per-request failure report: ``code`` in ``parse`` / ``schema`` / ``version``
/ ``oversize`` / ``reject`` / ``engine``, a human message, and the source
line when the failure is an intake failure).  Decoding is backward compatible: v1
documents (and v1 writers, which never emit ``"error"``) decode unchanged,
and requests are identical in both versions.

The ``spec``/``result`` payloads are exactly the canonical encodings of
``repro.experiments.sweep`` (``spec_to_dict`` / ``SweepResult.as_dict`` —
``inf`` spelled as the string ``"inf"``), so a response body is the same
document ``SweepResult.to_json`` writes, wrapped in routing metadata.
"""
from __future__ import annotations

import dataclasses
import json

from ..experiments.sweep import (SweepResult, WindowSweep, spec_from_dict,
                                 spec_to_dict)
from .api import SweepRequest, SweepResponse

__all__ = ["SCHEMA_VERSION", "SUPPORTED_VERSIONS", "WireError", "QueueItem",
           "encode_request", "decode_request", "encode_response",
           "decode_response", "encode_error", "read_queue",
           "write_responses", "serve_queue", "DEFAULT_MAX_LINE_BYTES"]

SCHEMA_VERSION = 2
SUPPORTED_VERSIONS = (1, 2)

#: intake guard: a single request line larger than this is answered with a
#: structured ``oversize`` error instead of being parsed (1 MiB is ~3 orders
#: of magnitude above any legitimate WindowSweep request).
DEFAULT_MAX_LINE_BYTES = 1 << 20


class UnsupportedVersion(ValueError):
    """A document's ``version`` field names a schema this build can't speak."""


@dataclasses.dataclass(frozen=True)
class WireError(Exception):
    """Structured per-request intake/serving failure.

    ``code`` is machine-readable: ``parse`` (not JSON), ``schema`` (JSON but
    not a well-formed request), ``version`` (unsupported schema version),
    ``oversize`` (line above the intake byte cap), ``reject`` (well-formed
    but refused by the service, e.g. a sharded spec with no service mesh),
    ``engine`` (the request was accepted but its device pass failed after
    retries).
    """

    code: str
    message: str
    lineno: int | None = None
    requester: str = "anon"
    request_id: str | None = None

    def __str__(self) -> str:  # Exception mixin: readable in tracebacks
        where = f" (line {self.lineno})" if self.lineno is not None else ""
        return f"[{self.code}]{where} {self.message}"


@dataclasses.dataclass(frozen=True)
class QueueItem:
    """One intake line: either a decoded request or a structured error."""

    lineno: int
    spec: WindowSweep | None = None
    requester: str = "anon"
    error: WireError | None = None


def _check_version(obj: dict, what: str) -> None:
    v = obj.get("version", SCHEMA_VERSION)
    if v not in SUPPORTED_VERSIONS:
        raise UnsupportedVersion(
            f"unsupported {what} schema version {v!r} "
            f"(this build speaks {', '.join(map(str, SUPPORTED_VERSIONS))})")


def encode_request(spec: WindowSweep, requester: str = "anon") -> dict:
    return {"version": SCHEMA_VERSION, "requester": requester,
            "spec": spec_to_dict(spec)}


def decode_request(obj: dict) -> tuple[WindowSweep, str]:
    """(spec, requester) from a request object; validates the version."""
    _check_version(obj, "request")
    return spec_from_dict(obj["spec"]), str(obj.get("requester", "anon"))


def encode_response(resp: SweepResponse) -> dict:
    out = {"version": SCHEMA_VERSION, "request_id": resp.request_id,
           "requester": resp.requester, "cached": resp.cached}
    if resp.error is not None:
        out["error"] = dict(resp.error)
    else:
        out["result"] = resp.result.as_dict()
    return out


def encode_error(err: WireError) -> dict:
    """Response document for a request that never reached the service."""
    body = {"code": err.code, "message": err.message}
    if err.lineno is not None:
        body["lineno"] = err.lineno
    rid = err.request_id or (
        f"line-{err.lineno}" if err.lineno is not None else "unknown")
    return {"version": SCHEMA_VERSION, "request_id": rid,
            "requester": err.requester, "error": body}


def decode_response(obj: dict) -> SweepResponse:
    _check_version(obj, "response")
    if "error" in obj:
        return SweepResponse(request_id=str(obj["request_id"]),
                             requester=str(obj.get("requester", "anon")),
                             spec=None, result=None, cached=False,
                             error=dict(obj["error"]))
    result = SweepResult.from_dict(obj["result"])
    return SweepResponse(request_id=str(obj["request_id"]),
                         requester=str(obj["requester"]),
                         spec=result.spec, result=result,
                         cached=bool(obj["cached"]))


def read_queue(path, *, max_line_bytes: int | None = DEFAULT_MAX_LINE_BYTES):
    """Lazily parse a JSONL queue file into :class:`QueueItem`\\ s.

    Yields one item per non-blank line, in file order, without ever loading
    the whole file: well-formed lines carry ``(spec, requester)``, bad lines
    carry a :class:`WireError` (``parse``/``schema``/``version``/
    ``oversize``) instead of aborting the rest of the queue.
    """
    with open(path) as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            if max_line_bytes is not None and len(line) > max_line_bytes:
                yield QueueItem(lineno=lineno, error=WireError(
                    "oversize",
                    f"request line is {len(line)} bytes "
                    f"(cap {max_line_bytes})", lineno=lineno))
                continue
            try:
                obj = json.loads(line)
            except json.JSONDecodeError as e:
                yield QueueItem(lineno=lineno, error=WireError(
                    "parse", f"not valid JSON: {e}", lineno=lineno))
                continue
            requester = "anon"
            if isinstance(obj, dict):
                requester = str(obj.get("requester", "anon"))
            try:
                spec, requester = decode_request(obj)
            except UnsupportedVersion as e:
                yield QueueItem(lineno=lineno, error=WireError(
                    "version", str(e), lineno=lineno, requester=requester))
                continue
            except Exception as e:
                yield QueueItem(lineno=lineno, error=WireError(
                    "schema", f"not a well-formed request: "
                    f"{type(e).__name__}: {e}",
                    lineno=lineno, requester=requester))
                continue
            yield QueueItem(lineno=lineno, spec=spec, requester=requester)


def write_responses(responses, fh) -> None:
    """Write responses as JSONL to an open text stream."""
    for resp in responses:
        fh.write(json.dumps(encode_response(resp)) + "\n")


def serve_queue(queue_path, out_fh, *, service=None,
                max_line_bytes: int | None = DEFAULT_MAX_LINE_BYTES
                ) -> "ServiceStats":
    """Drain a JSONL queue end-to-end; returns the service stats.

    The one-shot ``python -m repro.service`` entry point: builds a service
    (unless one is injected), submits every request line in file order, and
    writes one response line per input line, **in queue order**.

    Failure semantics (the hardening contract):

    * a malformed / oversized / unsupported-version line gets a structured
      ``error`` response at its queue position and the drain continues;
    * every response line is written *and flushed* as soon as it (and every
      line before it) is ready — a crash mid-drain keeps all
      already-computed responses on disk instead of losing the whole batch;
    * an engine failure (after the service's retry budget) surfaces as an
      ``engine`` error response for the affected requests only.
    """
    from .api import ServiceStats, SweepService  # noqa: F401 (return type)
    if service is None:
        service = SweepService()

    # one slot per queue line: either a ready-to-write error document or the
    # request_id whose response the slot waits for
    slots: list = []
    ready: dict[str, SweepResponse] = {}
    cursor = 0

    def flush() -> None:
        nonlocal cursor
        while cursor < len(slots):
            slot = slots[cursor]
            if isinstance(slot, dict):
                obj = slot
            elif slot in ready:
                obj = encode_response(ready[slot])
            else:
                return
            out_fh.write(json.dumps(obj) + "\n")
            out_fh.flush()
            cursor += 1

    def on_response(resp: SweepResponse) -> None:
        ready[resp.request_id] = resp
        flush()

    service.on_response = on_response
    for item in read_queue(queue_path, max_line_bytes=max_line_bytes):
        err = item.error
        if err is None:
            try:
                slots.append(
                    service.submit(item.spec, requester=item.requester)
                    .request_id)
                continue
            except Exception as e:     # e.g. sharded spec, no service mesh
                err = WireError("reject", f"{type(e).__name__}: {e}",
                                lineno=item.lineno, requester=item.requester)
        service.stats.n_errors += 1
        slots.append(encode_error(err))
    service.flush_ready()     # dedup/result-cache hits are ready immediately
    flush()
    while service.n_unserved:
        service.step(force=True)
    flush()
    return service.stats

"""Long-running daemon mode: fault-tolerant watch-directory serve loop.

``serve_daemon`` turns the one-shot queue drain into a service that faces
continuous traffic: clients drop wire-schema JSONL files into an intake
directory, the daemon batches each round's arrivals through the
``SweepService`` scheduler (coalescing, dedup, Eq. (3) fairness and the
per-round tenant quota), and appends one response line per request to the
output file as each result completes.

The hardening contract, mirroring the paper's motivation for the moving
window — bound the damage any one participant can cause:

* **malformed intake degrades per-line**: a bad JSON line, an unsupported
  schema version, or an oversized request gets a structured ``error``
  response at intake time; every other line in the file is still served;
* **engine failures degrade per-request**: a failing device pass is
  retried with capped backoff inside the service and then reported as an
  ``engine`` error response for exactly the requests it carried;
* **quotas bound tenants**: ``quota_rows`` meters any one requester's rows
  per round and ``fairness_rows`` applies Eq. (3) over cumulative served
  rows (the laggard is the GVT), so a flooding requester cannot stall a
  laggard beyond the fairness window;
* **state survives restarts**: the burned-state cache is persisted (npz +
  manifest, atomic rename) after every round that added rows, so a killed
  daemon's successor resumes from the burn-in work already paid for —
  responses stay bit-identical to an uninterrupted run;
* **SIGTERM flushes**: on SIGTERM/SIGINT the loop stops intake, force-
  drains every accepted request, flushes the responses, saves the cache,
  and exits 0.

Intake protocol: files matching ``*.jsonl`` in the intake directory are
processed in sorted-name order and renamed to ``<name>.done`` afterwards
(drop files via write-to-temp + rename to avoid partial reads).  A file
whose processing was cut short by a crash keeps its name and is simply
re-processed on restart — deterministic request ids and the result/state
caches make re-processing idempotent.  Responses are appended to
``out_path`` as they complete (not in intake order; correlate by
``request_id``), flushed line by line.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import sys
import time
from contextlib import nullcontext

from ..obs import Telemetry, write_snapshot
from ..obs.trace import TraceRecorder
from .wire import (DEFAULT_MAX_LINE_BYTES, WireError, encode_error,
                   encode_response, read_queue)

__all__ = ["DaemonConfig", "serve_daemon"]


@dataclasses.dataclass
class DaemonConfig:
    """Knobs of the serve loop (service-level knobs live on SweepService).

    Attributes:
      intake_dir: directory watched for ``*.jsonl`` request files.
      out_path: responses JSONL, append-mode, flushed per line.
      state_cache_path: persist the burned-state cache here (None = off).
      poll_interval_s: sleep between idle rounds.
      max_line_bytes: intake cap; longer lines get ``oversize`` errors.
      max_files_per_round: intake meter — at most this many request files
        are consumed per round (None = all available), bounding how long
        early arrivals wait behind a deep backlog before their first pass.
      idle_exit_rounds: exit cleanly after this many consecutive rounds
        with no intake, no passes, and nothing pending (None = run until
        signalled — the production mode).
      max_rounds: hard round cap (None = unbounded); a backstop for tests.
      crash_after_passes: fault injection for the crash/restart tests —
        hard-exit (``os._exit(70)``) at the end of the first round in
        which the service has executed at least this many passes, *after*
        responses and state cache hit disk.  None = disabled.
      metrics_dir: live exposition — after every busy round (and at exit)
        the telemetry registry is snapshotted into ``metrics.json`` +
        ``metrics.prom`` here, atomically (tmp+rename, the
        ``StateCache.save`` discipline), so a scraper never reads a torn
        file.  None = no exposition.
      trace_path: record a span per round and per coalesced pass and save
        the Chrome-trace JSON here at exit (including right before a
        ``crash_after_passes`` hard exit).  None = no tracing.
    """

    intake_dir: str
    out_path: str
    state_cache_path: str | None = None
    poll_interval_s: float = 0.25
    max_line_bytes: int = DEFAULT_MAX_LINE_BYTES
    max_files_per_round: int | None = None
    idle_exit_rounds: int | None = None
    max_rounds: int | None = None
    crash_after_passes: int | None = None
    metrics_dir: str | None = None
    trace_path: str | None = None


def _intake_files(cfg: DaemonConfig) -> list[str]:
    out_abs = os.path.abspath(cfg.out_path)
    names = []
    for name in sorted(os.listdir(cfg.intake_dir)):
        if not name.endswith(".jsonl"):
            continue
        path = os.path.join(cfg.intake_dir, name)
        if os.path.abspath(path) == out_abs:
            continue
        names.append(path)
    return names


def serve_daemon(cfg: DaemonConfig, *, service=None, log=None) -> "ServiceStats":
    """Run the watch-directory serve loop until signalled (or idle-exited).

    Returns the final :class:`~.api.ServiceStats`.  ``service`` defaults to
    a fresh :class:`~.api.SweepService`; pass one to set mesh / quota /
    retry knobs.  ``log`` is a callable for one-line progress messages
    (default: stderr).
    """
    from .api import ServiceStats, SweepService  # noqa: F401 (return type)
    if service is None:
        service = SweepService()
    if log is None:
        def log(msg):
            print(f"[repro.service.daemon] {msg}", file=sys.stderr, flush=True)

    # telemetry: reuse the service's bundle if it has one; otherwise build
    # whatever the exposition config needs (registry always, tracer only
    # when a trace is requested)
    tel = service.telemetry
    if tel is None and (cfg.metrics_dir or cfg.trace_path):
        tel = Telemetry(tracer=TraceRecorder() if cfg.trace_path else None)
        service.attach_telemetry(tel)
    elif tel is not None and cfg.trace_path and tel.tracer is None:
        tel.tracer = TraceRecorder()
    if tel is not None:
        rounds_total = tel.registry.counter(
            "repro_daemon_rounds", "serve-loop rounds completed")
        phase_seconds = tel.registry.histogram(
            "repro_daemon_phase_seconds",
            "daemon round phases: intake, flush, save "
            "(schedule/engine live in repro_service_phase_seconds)",
            unit="s")

    def save_metrics() -> None:
        if tel is not None and cfg.metrics_dir:
            write_snapshot(tel.registry, cfg.metrics_dir)

    def save_trace() -> None:
        if tel is not None and tel.tracer is not None and cfg.trace_path:
            tel.tracer.save(cfg.trace_path)

    os.makedirs(cfg.intake_dir, exist_ok=True)
    if cfg.state_cache_path and os.path.exists(cfg.state_cache_path):
        n = service.state_cache.load(cfg.state_cache_path)
        log(f"state cache: restored {n} burned row(s) from "
            f"{cfg.state_cache_path}" if n else
            f"state cache: {cfg.state_cache_path} unusable or empty, "
            f"starting cold")

    stop = {"sig": None}

    def _on_signal(signum, frame):
        stop["sig"] = signum

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, _on_signal)
        except ValueError:          # not the main thread: rely on the caller
            pass

    out_fh = open(cfg.out_path, "a")

    def emit(obj: dict) -> None:
        out_fh.write(json.dumps(obj) + "\n")
        out_fh.flush()

    service.on_response = lambda resp: emit(encode_response(resp))

    def save_cache() -> None:
        if cfg.state_cache_path and service.state_cache.dirty:
            service.state_cache.save(cfg.state_cache_path)

    rounds = idle = 0
    try:
        while stop["sig"] is None:
            rounds += 1
            prev = service.stats.snapshot()
            rspan = (tel.spans("round", cat="daemon",
                               args={"round": rounds})
                     if tel is not None else nullcontext())
            with rspan as sp:
                t0 = time.perf_counter()
                n_files = 0
                for path in _intake_files(cfg):
                    if stop["sig"] is not None:
                        break       # stop intake immediately on signal
                    if cfg.max_files_per_round is not None \
                            and n_files >= cfg.max_files_per_round:
                        break
                    n_files += 1
                    for item in read_queue(
                            path, max_line_bytes=cfg.max_line_bytes):
                        err = item.error
                        if err is None:
                            try:
                                service.submit(item.spec,
                                               requester=item.requester)
                                continue
                            except Exception as e:  # e.g. no service mesh
                                err = WireError(
                                    "reject", f"{type(e).__name__}: {e}",
                                    lineno=item.lineno,
                                    requester=item.requester)
                        service.stats.n_errors += 1
                        emit(encode_error(err))
                    os.replace(path, path + ".done")
                if tel is not None:
                    phase_seconds.observe(time.perf_counter() - t0,
                                          phase="intake")
                t0 = time.perf_counter()
                service.flush_ready()  # dedup/result hits: answer now
                if tel is not None:
                    phase_seconds.observe(time.perf_counter() - t0,
                                          phase="flush")
                n_passes = service.step(force=False)
                t0 = time.perf_counter()
                save_cache()
                if tel is not None:
                    phase_seconds.observe(time.perf_counter() - t0,
                                          phase="save")
                if sp is not None:
                    sp.args.update(n_files=n_files, n_passes=n_passes)
            busy = n_files or n_passes or service.n_unserved \
                or service.scheduler.n_pending
            if tel is not None:
                rounds_total.inc()
            if busy:
                # per-round *rates* (stats.diff vs the round-start
                # snapshot), not the ever-growing lifetime totals
                d = service.stats.diff(prev)
                log(f"round {rounds}: +{d.n_requests} request(s) "
                    f"(+{d.n_deduped} dedup), {n_passes} pass(es), "
                    f"+{d.rows_computed} rows computed, "
                    f"+{d.rows_from_state_cache} from state cache, "
                    f"+{d.n_errors} error(s)")
                save_metrics()
            if cfg.crash_after_passes is not None and \
                    service.stats.n_passes >= cfg.crash_after_passes:
                out_fh.flush()
                os.fsync(out_fh.fileno())
                save_metrics()
                save_trace()
                log(f"fault injection: crashing after "
                    f"{service.stats.n_passes} pass(es)")
                os._exit(70)
            idle = 0 if busy else idle + 1
            if cfg.idle_exit_rounds is not None \
                    and idle >= cfg.idle_exit_rounds:
                log(f"idle for {idle} round(s), exiting")
                break
            if cfg.max_rounds is not None and rounds >= cfg.max_rounds:
                log(f"round cap {cfg.max_rounds} reached, exiting")
                break
            if not busy:
                time.sleep(cfg.poll_interval_s)
        if stop["sig"] is not None:
            log(f"signal {stop['sig']}: flushing in-flight work")
        # clean shutdown: everything accepted gets its response flushed
        while service.n_unserved:
            service.step(force=True)
        save_cache()
        save_metrics()
        save_trace()
        s = service.stats
        log(f"served {s.n_requests} request(s), {s.n_errors} error(s), "
            f"{s.n_passes} pass(es), {s.rows_from_state_cache} rows from "
            f"state cache over {rounds} round(s)")
        return s
    finally:
        out_fh.close()
        for sig, handler in old_handlers.items():
            signal.signal(sig, handler)

"""Request/response core of the sweep service.

``SweepService`` accepts :class:`~repro.experiments.sweep.WindowSweep` specs
from many requesters and returns :class:`~repro.experiments.sweep.
SweepResult`\\ s, multiplexing compatible requests into shared device passes:

* specs are **canonicalized** (tuple-normalized field by field) and
  **fingerprinted**; identical specs dedup onto one computation and
  request ids are deterministic functions of ``(requester, spec)``;
* each (L, N_V) grid point becomes a :class:`~.scheduler.GridJob` whose
  rows are the exact ``(trial, Δ)`` coordinates ``run_window_sweep`` would
  use, so a coalesced pass can slice out, for every request, *bit-identical*
  rows to a direct run of that request's spec (tau/offset/u/gvt exact —
  the service's core contract, asserted in tests/test_service.py);
* the packed pass feeds the engine the per-row ``deltas=`` column and the
  per-row ``trial_base=`` vector (the PR's coalesced-batch engine operand),
  on any backend including ``sharded`` (mesh padding per
  ``plan_mesh_sweep`` conventions: Δ = inf pad rows on out-of-band stream
  indices, sliced off before reduction);
* burned-in states are cached row-granularly (:class:`~.state_cache.
  StateCache`) and reused across requests and refinement rounds.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import time
from contextlib import nullcontext

import numpy as np

from ..core import measurement
from ..core.engine import PDESEngine
from ..core.horizon import PDESConfig, SimState, StepStats
from ..experiments.sweep import (SweepRecord, SweepResult, WindowSweep,
                                 _derive_dist, _round_up, plan_mesh_sweep,
                                 spec_to_dict)
from .scheduler import BatchScheduler, CompatKey, GridJob, PackedPass
from .state_cache import StateCache

__all__ = ["SweepRequest", "SweepResponse", "ServiceStats", "SweepService",
           "canonicalize_spec", "spec_fingerprint"]


def canonicalize_spec(spec: WindowSweep) -> WindowSweep:
    """Field-normalized copy: tuples of python ints/floats, exact bools.

    Two submissions describing the same study compare (and fingerprint)
    equal after canonicalization regardless of whether they used lists,
    numpy scalars, or ints-for-floats.
    """
    return dataclasses.replace(
        spec,
        Ls=tuple(int(x) for x in spec.Ls),
        n_vs=tuple(int(x) for x in spec.n_vs),
        deltas=tuple(float(x) for x in spec.deltas),
        replicas=int(spec.replicas),
        n_steps=int(spec.n_steps),
        burn_in=None if spec.burn_in is None else int(spec.burn_in),
        backend=str(spec.backend),
        window=str(spec.window),
        k_fuse=int(spec.k_fuse),
        rd_mode=bool(spec.rd_mode),
        border_both=bool(spec.border_both),
        steady_frac=float(spec.steady_frac),
        seed=int(spec.seed),
    )


def spec_fingerprint(spec: WindowSweep) -> str:
    """Deterministic hex id of a canonicalized spec (the dedup key)."""
    blob = json.dumps(spec_to_dict(canonicalize_spec(spec)), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class SweepRequest:
    """One accepted submission.  ``request_id`` is deterministic:
    ``sha256(requester, canonical spec)`` — resubmitting the same spec from
    the same requester is idempotent."""

    request_id: str
    requester: str
    spec: WindowSweep        # canonicalized
    fingerprint: str         # canonical-spec hash (shared across requesters)


@dataclasses.dataclass(frozen=True)
class SweepResponse:
    """One served request.  ``cached`` marks results that required no new
    rows (the spec fingerprint was already computed or in flight).

    Exactly one of ``result``/``error`` is set: ``error`` (a structured
    ``{"code", "message", ...}`` dict, wire schema v2) reports a request
    whose device passes failed after the service's retry budget — the
    failure is scoped to the request, never to the whole drain.
    """

    request_id: str
    requester: str
    spec: WindowSweep | None
    result: SweepResult | None
    cached: bool
    error: dict | None = None


@dataclasses.dataclass
class ServiceStats:
    """Work accounting — what the dedup/cache tests and the bench gate read.

    ``engine_row_steps`` is the honest compute unit (rows × steps summed
    over every engine call, burn and measure alike): coalescing, dedup and
    the state cache all show up as this number shrinking versus the serial
    per-request baseline.
    """

    n_requests: int = 0
    n_deduped: int = 0            # served without creating any new jobs
    n_passes: int = 0             # coalesced measurement passes executed
    n_engine_calls: int = 0       # burn sub-passes + measurement passes
    n_errors: int = 0             # requests answered with an error response
    n_retries: int = 0            # engine-pass retries (capped backoff)
    rows_requested: int = 0       # sum of request row counts (pre-dedup)
    rows_computed: int = 0        # union rows measured on-device
    rows_burned: int = 0          # rows burned on-device (state-cache misses)
    rows_from_state_cache: int = 0
    engine_row_steps: int = 0
    state_cache_hits: int = 0     # mirrors StateCache counters (hit/miss/
    state_cache_misses: int = 0   # eviction) so cache thrash under max_rows
    state_cache_evictions: int = 0  # pressure is visible in every summary

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    def snapshot(self) -> "ServiceStats":
        """An immutable-in-practice copy of the current totals.

        Take one at a round boundary, then ``diff`` against it after: the
        daemon's round log and the metrics sink report per-round *rates*
        this way instead of ever-growing lifetime totals.
        """
        return dataclasses.replace(self)

    def diff(self, prev: "ServiceStats") -> "ServiceStats":
        """Field-wise ``self - prev``: the work done since ``prev``."""
        return ServiceStats(**{
            f.name: getattr(self, f.name) - getattr(prev, f.name)
            for f in dataclasses.fields(self)})


@dataclasses.dataclass
class _PendingRequest:
    request: SweepRequest
    cached: bool                  # True -> served from the result cache


# paper observables live in known ranges: u / rate are fractions of a step,
# occupancy is Δτ/Δ in [0, ~1]; w2 spans decades with L, so octave buckets
_FRACTION_BUCKETS = (0.05, 0.1, 0.15, 0.2, 0.25, 0.3, 0.4, 0.5,
                     0.6, 0.7, 0.8, 0.9, 1.0)
_W2_BUCKETS = (0.25, 0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0,
               128.0, 256.0)


class _ServiceInstruments:
    """The service's metric handles, bound to one registry.

    Every instrument here observes host-side values the service already
    materialized (``ServiceStats`` totals, scheduler ledgers, the per-pass
    numpy stats block) — the off-path contract that keeps telemetry-on
    responses bit-identical to telemetry-off (tests/test_obs.py).
    """

    def __init__(self, registry):
        h, c, g = registry.histogram, registry.counter, registry.gauge
        # -- the paper's own observables, live per coalesced pass
        self.pass_u = h("repro_pass_u",
                        "per-pass mean utilization <u> (fraction of PEs "
                        "advancing; Figs. 2/5/6)", unit="fraction",
                        buckets=_FRACTION_BUCKETS)
        self.pass_w2 = h("repro_pass_w2",
                         "per-pass mean horizon width <w^2> (Eq. 4, "
                         "Fig. 9)", unit="tau^2", buckets=_W2_BUCKETS)
        self.pass_rate = h("repro_pass_gvt_rate",
                           "per-pass mean GVT progress rate (Sec. V)",
                           unit="tau_per_step", buckets=_FRACTION_BUCKETS)
        self.pass_occupancy = h(
            "repro_pass_window_occupancy",
            "per-pass mean horizon spread over window width, "
            "<max tau - min tau>/Delta (Eq. 3 slack)", unit="fraction",
            buckets=_FRACTION_BUCKETS)
        self.pass_rows = h("repro_pass_rows",
                           "union rows per coalesced pass", unit="rows",
                           buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256,
                                    512, 1024, 2048, 4096))
        # -- service health: ServiceStats mirrored as counters
        self.totals = {
            "n_requests": c("repro_service_requests",
                            "requests accepted (post-idempotence)"),
            "n_deduped": c("repro_service_dedup_hits",
                           "requests served without new jobs"),
            "n_passes": c("repro_service_passes",
                          "coalesced measurement passes executed"),
            "n_engine_calls": c("repro_service_engine_calls",
                                "engine invocations (burn + measure)"),
            "n_errors": c("repro_service_errors",
                          "requests answered with an error response"),
            "n_retries": c("repro_service_engine_retries",
                           "engine-pass retries (capped backoff)"),
            "rows_requested": c("repro_service_rows_requested",
                                "request row counts, pre-dedup",
                                unit="rows"),
            "rows_computed": c("repro_service_rows_computed",
                               "union rows measured on-device",
                               unit="rows"),
            "rows_burned": c("repro_service_rows_burned",
                             "rows burned on-device (cache misses)",
                             unit="rows"),
            "rows_from_state_cache": c(
                "repro_service_rows_from_state_cache",
                "measurement rows whose burn-in was reused", unit="rows"),
            "engine_row_steps": c("repro_service_engine_row_steps",
                                  "rows x steps over every engine call "
                                  "(the honest compute unit)",
                                  unit="row_steps"),
            "state_cache_hits": c("repro_service_state_cache_hits",
                                  "burned-state cache row hits"),
            "state_cache_misses": c("repro_service_state_cache_misses",
                                    "burned-state cache row misses"),
            "state_cache_evictions": c(
                "repro_service_state_cache_evictions",
                "burned-state cache rows evicted (max_rows pressure)"),
        }
        self.fairness_throttles = c(
            "repro_service_fairness_throttles",
            "jobs deferred by the Eq. (3) fairness window")
        self.quota_throttles = c(
            "repro_service_quota_throttles",
            "jobs deferred by the per-round requester quota")
        self.served_rows = c("repro_service_served_rows",
                             "rows served, per requester", unit="rows")
        self.queue_depth = g("repro_service_queue_depth",
                             "grid jobs pending in the scheduler",
                             unit="jobs")
        self.coalescing_ratio = g(
            "repro_service_coalescing_ratio",
            "rows_requested / rows_computed — dedup + row-sharing win",
            unit="ratio")
        self.state_cache_rows = g("repro_service_state_cache_rows",
                                  "burned rows currently cached",
                                  unit="rows")
        self.phase_seconds = h("repro_service_phase_seconds",
                               "service step phases: schedule (take) and "
                               "engine (pass execution)", unit="s")


class SweepService:
    """Batched request/response front end over the sweep engine.

    Args:
      mesh / dist: device mesh (required for ``backend="sharded"`` specs)
        and optional ``DistConfig``.
      max_batch_rows / max_wait_rounds / fairness_rows / quota_rows:
        admission control, see :class:`~.scheduler.BatchScheduler`
        (``quota_rows`` caps any one requester's rows per scheduling round;
        ``fairness_rows`` is the Eq. (3) window over cumulative served rows).
      state_cache_rows: LRU bound of the burned-state cache, in rows.
      engine_retries / retry_base_s / retry_cap_s: a failing device pass is
        retried up to ``engine_retries`` times with capped exponential
        backoff (``min(retry_cap_s, retry_base_s * 2**attempt)``); a pass
        that still fails is reported per-request as a structured ``engine``
        error response — never by aborting the drain.
      telemetry: an optional :class:`repro.obs.Telemetry` bundle.  When
        set, the service mirrors its stats into live metrics, observes the
        paper observables (⟨u⟩, ⟨w²⟩, GVT rate, window occupancy) per
        pass, and — if the bundle carries a tracer — emits one span per
        :class:`~.scheduler.PackedPass` annotated with the CompatKey, row
        counts, and cache provenance.  Strictly off-path: responses are
        bit-identical with or without it.

    ``submit`` registers a request; ``step`` runs one scheduling round;
    ``drain`` forces everything through and returns responses in
    submission order.  Setting ``on_response`` (a callable taking one
    :class:`SweepResponse`) switches the service to streaming emission:
    every response is delivered through the callback as soon as its result
    (or error) is ready — after each individual pass, not at drain time —
    which is what lets ``wire.serve_queue`` and the daemon flush completed
    work to disk before later passes run (or crash).
    """

    def __init__(self, *, mesh=None, dist=None, max_batch_rows: int = 4096,
                 max_wait_rounds: int = 0, fairness_rows: float = math.inf,
                 quota_rows: float = math.inf, state_cache_rows: int = 65536,
                 engine_retries: int = 0, retry_base_s: float = 0.05,
                 retry_cap_s: float = 2.0, telemetry=None):
        self.mesh = mesh
        self.dist = dist
        self.scheduler = BatchScheduler(max_batch_rows=max_batch_rows,
                                        max_wait_rounds=max_wait_rounds,
                                        fairness_rows=fairness_rows,
                                        quota_rows=quota_rows)
        self.state_cache = StateCache(max_rows=state_cache_rows)
        self.stats = ServiceStats()
        self.engine_retries = engine_retries
        self.retry_base_s = retry_base_s
        self.retry_cap_s = retry_cap_s
        self.attach_telemetry(telemetry)
        self.on_response = None                           # streaming sink
        self._seq = 0
        self._pending: dict[str, _PendingRequest] = {}   # rid -> request
        self._order: list[str] = []                       # rids, FIFO
        self._results: dict[str, SweepResult] = {}        # fp -> result
        self._fp_specs: dict[str, WindowSweep] = {}       # fp -> spec
        self._fp_jobs_left: dict[str, int] = {}           # fp -> undone jobs
        self._fp_records: dict[str, dict] = {}            # fp -> {(L,nv): recs}
        self._fp_errors: dict[str, dict] = {}             # fp -> error body
        self._served_rows: dict[str, int] = {}            # requester -> rows

    def attach_telemetry(self, telemetry) -> None:
        """Attach (or detach, with None) a ``repro.obs.Telemetry`` bundle."""
        self.telemetry = telemetry
        self._ins = (None if telemetry is None
                     else _ServiceInstruments(telemetry.registry))

    # -- request intake ----------------------------------------------------

    def submit(self, spec: WindowSweep, requester: str = "anon"
               ) -> SweepRequest:
        """Register a sweep request; returns its deterministic id."""
        spec = canonicalize_spec(spec)
        fp = spec_fingerprint(spec)
        rid = hashlib.sha256(f"{requester}\n{fp}".encode()).hexdigest()[:16]
        req = SweepRequest(request_id=rid, requester=requester, spec=spec,
                           fingerprint=fp)
        if rid in self._pending:          # idempotent resubmission
            return self._pending[rid].request
        self.stats.n_requests += 1
        self.stats.rows_requested += (
            len(spec.Ls) * len(spec.n_vs) * spec.n_trajectories)
        cached = fp in self._results or fp in self._fp_jobs_left
        if cached:
            self.stats.n_deduped += 1
        else:
            # a fingerprint that previously *failed* is retried from scratch
            self._fp_errors.pop(fp, None)
            self._enqueue_jobs(req)
        self._pending[rid] = _PendingRequest(request=req, cached=cached)
        self._order.append(rid)
        return req

    def _enqueue_jobs(self, req: SweepRequest) -> None:
        spec = req.spec
        self._fp_specs[req.fingerprint] = spec
        self._fp_records[req.fingerprint] = {}
        if spec.backend == "sharded":
            if self.mesh is None:
                raise ValueError(
                    "backend='sharded' requests need a service mesh: "
                    "construct SweepService(mesh=...)")
            plans = plan_mesh_sweep(spec, self.mesh, self.dist)
            points = [(p.L, p.n_v, p.trial_base, p.burn_in) for p in plans]
        else:
            points, base = [], 0
            for L in spec.Ls:
                for n_v in spec.n_vs:
                    cfg = PDESConfig(L=int(L), n_v=int(n_v), delta=math.inf,
                                     rd_mode=spec.rd_mode,
                                     border_both=spec.border_both)
                    points.append((int(L), int(n_v), base,
                                   spec.burn_in_for(cfg)))
                    base += spec.n_trajectories
        self._fp_jobs_left[req.fingerprint] = len(points)
        R = spec.replicas
        for L, n_v, base, burn in points:
            key = CompatKey(L=L, n_v=n_v, backend=spec.backend,
                            window=spec.window, k_fuse=spec.k_fuse,
                            rd_mode=spec.rd_mode,
                            border_both=spec.border_both, seed=spec.seed,
                            burn=burn, n_steps=spec.n_steps)
            rows = tuple((base + w * R + r, d)
                         for w, d in enumerate(spec.deltas)
                         for r in range(R))
            self.scheduler.enqueue(GridJob(
                fp=req.fingerprint, requester=req.requester, seq=self._seq,
                key=key, rows=rows, deltas=tuple(spec.deltas), replicas=R,
                steady_frac=spec.steady_frac))
            self._seq += 1

    # -- scheduling / execution -------------------------------------------

    def step(self, force: bool = False) -> int:
        """One scheduling round; returns the number of passes executed.

        Fairness sees only requesters with *pending* work: the Eq. (3) GVT
        is the laggard among active tenants, so a requester who went idle
        can never permanently block the window for everyone still queued.
        """
        ins = self._ins
        t0 = time.perf_counter() if ins is not None else 0.0
        active = self.scheduler.pending_requesters
        served = {r: n for r, n in self._served_rows.items() if r in active}
        passes = self.scheduler.take(served, force=force)
        if ins is not None:
            ins.phase_seconds.observe(time.perf_counter() - t0,
                                      phase="schedule")
            t0 = time.perf_counter()
        for p in passes:
            self._run_pass(p)
        if ins is not None and passes:
            ins.phase_seconds.observe(time.perf_counter() - t0,
                                      phase="engine")
        self._sync_cache_stats()
        self._sync_metrics()
        return len(passes)

    def _run_pass(self, p: PackedPass) -> None:
        """Execute one pass with capped-backoff retries; on final failure,
        fail the pass's requests (structured ``engine`` error responses)
        instead of propagating — one bad pass never poisons the drain."""
        delay = self.retry_base_s
        for attempt in range(self.engine_retries + 1):
            try:
                self._execute(p)
                break
            except Exception as exc:  # noqa: BLE001 — degraded, not dead
                if attempt == self.engine_retries:
                    self._fail_pass(p, exc)
                    break
                self.stats.n_retries += 1
                time.sleep(min(delay, self.retry_cap_s))
                delay *= 2
        self.flush_ready()

    def _fail_pass(self, p: PackedPass, exc: Exception) -> None:
        body = {"code": "engine",
                "message": f"{type(exc).__name__}: {exc}"}
        fps = {job.fp for job in p.jobs}
        for fp in fps:
            self._fp_errors[fp] = body
            self._fp_jobs_left.pop(fp, None)
            self._fp_records.pop(fp, None)
        # sibling grid-point jobs of a failed fingerprint are moot: drop
        # them rather than compute rows nobody can be answered with
        self.scheduler.drop_fps(fps)

    @property
    def n_unserved(self) -> int:
        """Accepted requests not yet answered (streamed or drained)."""
        return len(self._pending)

    def _response_for(self, rid: str) -> SweepResponse | None:
        """The finished response for ``rid``, or None if not ready."""
        pend = self._pending[rid]
        fp = pend.request.fingerprint
        if fp in self._results:
            return SweepResponse(
                request_id=rid, requester=pend.request.requester,
                spec=pend.request.spec, result=self._results[fp],
                cached=pend.cached)
        if fp in self._fp_errors:
            return SweepResponse(
                request_id=rid, requester=pend.request.requester,
                spec=pend.request.spec, result=None, cached=False,
                error=self._fp_errors[fp])
        return None

    def flush_ready(self) -> int:
        """Deliver every finished response through ``on_response``.

        No-op without a streaming sink.  Called after each executed pass,
        so completed work reaches the sink (and its disk flush) before any
        later pass runs — the mid-drain crash-tolerance mechanism.
        """
        if self.on_response is None:
            return 0
        emitted = 0
        for rid in list(self._order):
            if rid not in self._pending:
                continue
            resp = self._response_for(rid)
            if resp is None:
                continue
            del self._pending[rid]
            if resp.error is not None:
                self.stats.n_errors += 1
            self.on_response(resp)
            emitted += 1
        if emitted:
            self._order = [r for r in self._order if r in self._pending]
        return emitted

    def drain(self) -> list[SweepResponse]:
        """Force everything through; responses in submission order.

        With a streaming ``on_response`` sink, responses already delivered
        through the sink are not returned again.
        """
        while self.scheduler.n_pending:
            self.step(force=True)
        self.flush_ready()
        out = []
        for rid in self._order:
            if rid not in self._pending:
                continue
            resp = self._response_for(rid)
            assert resp is not None, f"drained with unserved request {rid}"
            if resp.error is not None:
                self.stats.n_errors += 1
            out.append(resp)
        self._pending.clear()
        self._order.clear()
        self._sync_cache_stats()
        self._sync_metrics()
        return out

    def _sync_cache_stats(self) -> None:
        self.stats.state_cache_hits = self.state_cache.hits
        self.stats.state_cache_misses = self.state_cache.misses
        self.stats.state_cache_evictions = self.state_cache.evictions

    def _sync_metrics(self) -> None:
        """Mirror the stats ledgers into the attached metrics registry.

        ``set_total`` (not ``inc``): ``ServiceStats`` and the scheduler
        already accumulate; the registry is a read-out, never a second
        ledger that could drift.
        """
        ins = self._ins
        if ins is None:
            return
        stats = self.stats.as_dict()
        for field, counter in ins.totals.items():
            counter.set_total(stats[field])
        ins.fairness_throttles.set_total(self.scheduler.fairness_deferrals)
        ins.quota_throttles.set_total(self.scheduler.quota_deferrals)
        for requester, rows in self._served_rows.items():
            ins.served_rows.set_total(rows, requester=requester)
        ins.queue_depth.set(self.scheduler.n_pending)
        ins.coalescing_ratio.set(
            self.stats.rows_requested / max(self.stats.rows_computed, 1))
        ins.state_cache_rows.set(len(self.state_cache))

    # -- one coalesced pass -----------------------------------------------

    def _engine(self, key: CompatKey) -> PDESEngine:
        cfg = PDESConfig(L=key.L, n_v=key.n_v, delta=math.inf,
                         rd_mode=key.rd_mode, border_both=key.border_both)
        mesh = self.mesh if key.backend == "sharded" else None
        return PDESEngine(cfg, backend=key.backend, window=key.window,
                          k_fuse=key.k_fuse, mesh=mesh,
                          dist=self.dist if mesh is not None else None)

    def _ens_extent(self, key: CompatKey) -> int:
        if key.backend != "sharded":
            return 1
        dist = self.dist
        if dist is None:
            spec_like = WindowSweep(window=key.window, k_fuse=key.k_fuse)
            dist = _derive_dist(spec_like)
        ens = 1
        for a in dist.ens_axes:
            ens *= self.mesh.shape[a]
        return ens

    def _execute(self, p: PackedPass) -> None:
        import jax.numpy as jnp
        key = p.key
        eng = self._engine(key)
        B = p.n_rows
        ens = self._ens_extent(key)
        n_pad = _round_up(B, ens) - B
        trials = np.fromiter((t for t, _ in p.rows), np.int32, B)
        deltas = np.fromiter((d for _, d in p.rows), np.float32, B)
        if n_pad:
            # pad rows run unconstrained on out-of-band stream indices and
            # are sliced off before any reduction (plan_mesh_sweep contract)
            trials = np.concatenate(
                [trials, -1 - np.arange(n_pad, dtype=np.int32)])
            deltas = np.concatenate(
                [deltas, np.full(n_pad, np.inf, np.float32)])
        drows = jnp.asarray(deltas)
        tvec = jnp.asarray(trials)

        ctx = nullcontext() if self.telemetry is None else \
            self.telemetry.spans("pass", cat="service", args=dict(
                dataclasses.asdict(key), n_rows=B, n_pad=n_pad,
                n_jobs=len(p.jobs),
                requesters=sorted({j.requester for j in p.jobs})))
        with ctx as sp:
            pre_cached = self.stats.rows_from_state_cache
            pre_burned = self.stats.rows_burned
            state = self._burned_state(eng, key, p.rows, n_pad, drows, tvec)
            _, stats = eng.run(state, key.seed, key.n_steps, deltas=drows,
                               trial_base=tvec)
            self.stats.n_passes += 1
            self.stats.n_engine_calls += 1
            self.stats.rows_computed += B
            self.stats.engine_row_steps += (B + n_pad) * key.n_steps

            arrs = StepStats(*(np.asarray(a)[:, :B] for a in stats))
            if sp is not None:
                sp.args.update(
                    rows_from_cache=(self.stats.rows_from_state_cache
                                     - pre_cached),
                    rows_burned=self.stats.rows_burned - pre_burned)
            if self._ins is not None:
                self._observe_pass(p, arrs, deltas[:B])
            for job, cols in zip(p.jobs, p.cols):
                idx = np.asarray(cols, np.intp)
                # fancy indexing yields F-ordered columns; numpy's axis-0
                # mean sums in a layout-dependent order, so restore C order
                # to keep the reduction bit-identical to a direct (T, B) run
                sliced = StepStats(*(np.ascontiguousarray(a[:, idx])
                                     for a in arrs))
                red = measurement.sweep_reduce(
                    sliced, len(job.deltas), job.replicas,
                    steady_frac=job.steady_frac)
                self._served_rows[job.requester] = (
                    self._served_rows.get(job.requester, 0) + len(job.rows))
                self._finish_job(job, red)

    def _observe_pass(self, p: PackedPass, arrs: StepStats,
                      deltas: np.ndarray) -> None:
        """Observe the paper observables from an already-materialized pass.

        Pure numpy over the (T, B) host stats block ``_execute`` built
        anyway — no device work, no effect on what any requester receives.
        """
        ins = self._ins
        ins.pass_u.observe(float(arrs.utilization.mean()))
        ins.pass_w2.observe(float(arrs.w2.mean()))
        ins.pass_rows.observe(float(p.n_rows))
        T = arrs.gvt.shape[0]
        if T > 1:
            rate = (arrs.gvt[-1] - arrs.gvt[0]) / (T - 1)
            ins.pass_rate.observe(float(rate.mean()))
        finite = np.isfinite(deltas)
        if finite.any():
            # horizon extent per row (spread = max_dev + min_dev, as in
            # measurement.sweep_reduce), over the width Δ that bounds it
            occ = (arrs.max_dev + arrs.min_dev).mean(axis=0)[finite] \
                / deltas[finite]
            ins.pass_occupancy.observe(float(occ.mean()))

    def _burned_state(self, eng: PDESEngine, key: CompatKey, rows,
                      n_pad: int, drows, tvec) -> SimState:
        """Assemble the post-burn-in state, reusing cached rows.

        Rows are independent rings, so cache-missing rows are burned in
        their own sub-pass and spliced next to cached rows — bit-identical
        to burning the whole batch (tests/test_service.py).
        """
        import jax.numpy as jnp
        B = len(rows)
        if not key.burn:
            return eng.init(B + n_pad)
        skey = key.stream_key
        cached = [self.state_cache.get(skey + r) for r in rows]
        missing = [i for i, c in enumerate(cached) if c is None]
        self.stats.rows_from_state_cache += B - len(missing)
        if missing:
            ens = self._ens_extent(key)
            m_pad = _round_up(len(missing), ens) - len(missing)
            m_idx = np.asarray(missing, np.intp)
            m_trials = np.concatenate(
                [np.asarray(tvec)[m_idx],
                 -1 - np.arange(m_pad, dtype=np.int32)])
            m_deltas = np.concatenate(
                [np.asarray(drows)[m_idx],
                 np.full(m_pad, np.inf, np.float32)])
            sub = eng.burn_in(eng.init(len(missing) + m_pad), key.seed,
                              key.burn, deltas=jnp.asarray(m_deltas),
                              trial_base=jnp.asarray(m_trials, jnp.int32))
            self.stats.n_engine_calls += 1
            self.stats.rows_burned += len(missing)
            self.stats.engine_row_steps += (len(missing) + m_pad) * key.burn
            self.state_cache.put_batch(
                [skey + rows[i] for i in missing],
                np.asarray(sub.tau)[:len(missing)],
                np.asarray(sub.offset)[:len(missing)],
                np.asarray(sub.offset_comp)[:len(missing)])
            for j, i in enumerate(missing):
                cached[i] = (np.asarray(sub.tau)[j],
                             np.asarray(sub.offset)[j],
                             np.asarray(sub.offset_comp)[j])
        L = eng.cfg.L
        tau = np.zeros((B + n_pad, L), np.float32)
        off = np.zeros((B + n_pad,), np.float32)
        comp = np.zeros((B + n_pad,), np.float32)
        for i, (t, o, c) in enumerate(cached):
            tau[i], off[i], comp[i] = t, o, c
        return SimState(jnp.asarray(tau), jnp.asarray(off),
                        jnp.asarray(comp), jnp.int32(key.burn))

    # -- per-request assembly ---------------------------------------------

    def _finish_job(self, job: GridJob, red: dict) -> None:
        if job.fp in self._fp_errors:
            return        # a sibling pass already failed this fingerprint
        recs = []
        for w, d in enumerate(job.deltas):
            recs.append(SweepRecord(
                L=job.key.L, n_v=job.key.n_v, delta=float(d),
                u=float(red["u"][w]), u_err=float(red["u_err"][w]),
                w2=float(red["w2"][w]), w2_err=float(red["w2_err"][w]),
                w=float(red["w"][w]), wa=float(red["wa"][w]),
                spread=float(red["spread"][w]),
                rate=float(red["rate"][w]),
                rate_err=float(red["rate_err"][w])))
        self._fp_records[job.fp][(job.key.L, job.key.n_v)] = recs
        self._fp_jobs_left[job.fp] -= 1
        if self._fp_jobs_left[job.fp] == 0:
            spec = self._fp_specs[job.fp]
            records = []
            for L in spec.Ls:
                for n_v in spec.n_vs:
                    records.extend(
                        self._fp_records[job.fp][(int(L), int(n_v))])
            self._results[job.fp] = SweepResult(spec=spec,
                                                records=tuple(records))
            del self._fp_jobs_left[job.fp]
            del self._fp_records[job.fp]

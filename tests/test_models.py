"""Per-architecture smoke tests: reduced config, one forward/train step on CPU.

Asserts output shapes and absence of NaNs for all 10 assigned architectures,
plus decode-vs-prefill consistency and differentiability per family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import build_model

KEY = jax.random.key(0)
B, S = 2, 64


def make_batch(cfg):
    d = cfg.d_model
    tokens = jax.random.randint(jax.random.fold_in(KEY, 1), (B, S), 0,
                                cfg.vocab_size)
    labels = jax.random.randint(jax.random.fold_in(KEY, 2), (B, S), 0,
                                cfg.vocab_size)
    if cfg.family == "encdec":
        enc = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, d)) * 0.1
        return {"enc_embeddings": enc, "tokens": tokens, "labels": labels}
    if cfg.input_mode == "embeddings":
        emb = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, d)) * 0.1
        return {"embeddings": emb, "tokens": tokens, "labels": labels}
    return {"tokens": tokens, "labels": labels}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.fold_in(KEY, 7))
    loss, metrics = jax.jit(model.loss)(params, make_batch(cfg))
    assert loss.shape == ()
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0
    for k, v in metrics.items():
        assert np.isfinite(float(v)), (arch, k)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-2b", "mixtral-8x7b",
                                  "mamba2-130m", "zamba2-2.7b",
                                  "h2o-danube-3-4b"])
def test_arch_smoke_decode(arch):
    """prefill + a few decode steps: shapes and finiteness."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.fold_in(KEY, 8))
    batch = make_batch(cfg)
    logits, cache = jax.jit(model.prefill)(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    step = jax.jit(model.decode_step)
    for i in range(3):
        logits, cache = step(params, cache, tok, jnp.int32(S + i))
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), arch
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


def test_whisper_decode():
    cfg = get_config("whisper-base").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.fold_in(KEY, 9))
    batch = make_batch(cfg)
    _, cache = jax.jit(model.prefill, static_argnames=("max_decode_len",))(
        params, batch, max_decode_len=16)
    tok = jnp.zeros((B, 1), jnp.int32)
    step = jax.jit(model.decode_step)
    for i in range(3):
        logits, cache = step(params, cache, tok, jnp.int32(i))
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all()
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-130m"])
def test_decode_matches_prefill(arch):
    """Feeding tokens one-by-one through decode must reproduce prefill logits."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.fold_in(KEY, 10))
    tokens = jax.random.randint(jax.random.fold_in(KEY, 11), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    logits_pre, _ = jax.jit(model.prefill)(params, batch)

    # decode path: start from an empty cache and feed the same tokens
    if cfg.family == "ssm":
        cache = model.init_cache(B)
    else:
        cache = model.cache_spec(B, S)
    step = jax.jit(model.decode_step)
    logits = None
    for i in range(S):
        logits, cache = step(params, cache, tokens[:, i:i + 1], jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(logits_pre),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["llama3.2-1b", "mixtral-8x7b", "mamba2-130m",
                                  "zamba2-2.7b", "whisper-base"])
def test_family_differentiable(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.fold_in(KEY, 12))
    batch = make_batch(cfg)

    def f(p):
        loss, _ = model.loss(p, batch)
        return loss

    loss, grads = jax.jit(jax.value_and_grad(f))(params)
    assert np.isfinite(float(loss))
    gnorm = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g.astype(jnp.float32)))),
        grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0

"""Config registry integrity + serve engine end-to-end on a reduced model."""

import jax
import numpy as np
import pytest

from repro.configs import (ARCH_IDS, SHAPES, cell_is_runnable, get_config,
                           get_shape)
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine

EXPECTED_PARAMS_B = {
    "internvl2-76b": (65, 76),    # LLM backbone only; +6B stubbed ViT
    "gemma2-2b": (2.0, 3.3),
    "qwen2.5-3b": (2.5, 3.6),
    "llama3.2-1b": (1.0, 1.5),
    "h2o-danube-3-4b": (3.3, 4.5),
    "whisper-base": (0.05, 0.12),
    "zamba2-2.7b": (2.0, 3.0),
    "mixtral-8x7b": (44, 49),
    "arctic-480b": (450, 500),
    "mamba2-130m": (0.1, 0.18),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_counts_match_public_configs(arch):
    cfg = get_config(arch)
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = cfg.n_params() / 1e9
    assert lo <= n <= hi, (arch, n)


def test_active_params_moe():
    mix = get_config("mixtral-8x7b")
    assert mix.n_active_params() < 0.35 * mix.n_params()
    arc = get_config("arctic-480b")
    assert arc.n_active_params() < 0.05 * arc.n_params()


def test_registry_and_shapes():
    assert len(ARCH_IDS) == 10 and len(SHAPES) == 4
    assert get_shape("train_4k").kind == "train"
    assert get_shape("long_500k").seq_len == 524_288
    with pytest.raises(KeyError):
        get_config("nonexistent")


def test_cell_skip_rule():
    assert not cell_is_runnable("llama3.2-1b", "long_500k")
    assert cell_is_runnable("mamba2-130m", "long_500k")
    assert cell_is_runnable("llama3.2-1b", "train_4k")
    # 40 cells - 5 long-context skips
    runnable = sum(cell_is_runnable(a, s) for a in ARCH_IDS for s in SHAPES)
    assert runnable == 35


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_configs_are_tiny(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_params() < 5e7
    assert cfg.family == get_config(arch).family


def test_serve_engine_end_to_end():
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = ServeEngine(model, params, batch_lanes=2, max_len=64, delta=8.0)
    rng = np.random.default_rng(0)
    for uid in range(3):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size, 8,
                                               dtype=np.int32),
                           max_new_tokens=6))
    results = eng.run()
    assert set(results) == {0, 1, 2}
    for r in results.values():
        assert 1 <= len(r.tokens) <= 6
        assert all(0 <= t < cfg.vocab_size for t in r.tokens)
    assert 0.0 < eng.lane_utilization <= 1.0

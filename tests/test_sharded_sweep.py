"""Multi-device window-sweep sharding: bit-identity and ragged padding.

The expensive parity checks run in one subprocess with 8 fake CPU devices
(the main pytest process must keep the default 1-device platform, same
pattern as tests/test_distributed_pdes.py): a 2x4 mesh runs the batched
sharded sweep and is compared row-block by row-block against the
single-device serial per-Δ loop — ``array_equal``, not ``allclose``, on
trajectories.  The in-process tests cover the mesh grid scheduler
(``plan_mesh_sweep``) and its error paths on an AbstractMesh, which needs
axis sizes only.
"""
import json
import math
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.distributed

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, math
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.compat import make_mesh
    from repro.core import PDESConfig
    from repro.core.engine import PDESEngine
    from repro.experiments.sweep import (WindowSweep, plan_mesh_sweep,
                                         run_window_sweep,
                                         serial_window_sweep)

    results = {}
    mesh = make_mesh((2, 4), ("data", "model"))

    # -- engine-level: batched sharded sweep vs single-device serial loop --
    cfg = PDESConfig(L=32, n_v=4, delta=4.0)
    e_sh = PDESEngine(cfg, backend="sharded", k_fuse=4, mesh=mesh)
    e_1d = PDESEngine(cfg, backend="reference", k_fuse=4)
    deltas = [1.0, 2.0, 4.0, math.inf]
    R = 3
    st0, drows = e_sh.init_sweep(deltas, replicas=R)
    ss, sw = e_sh.run(st0, seed=5, n_steps=16, deltas=drows)
    bitident = True
    for w, d in enumerate(deltas):
        s1 = e_1d.init(R)
        s1, _ = e_1d.run(s1, seed=5, n_steps=16,
                         deltas=jnp.full((R,), d, jnp.float32),
                         trial_base=w * R)
        blk = slice(w * R, (w + 1) * R)
        bitident &= bool(np.array_equal(np.asarray(s1.tau),
                                        np.asarray(ss.tau[blk])))
        bitident &= bool(np.array_equal(np.asarray(s1.offset),
                                        np.asarray(ss.offset[blk])))
    results["engine_bit_identity"] = bitident

    # stats contract: u/gvt exactly equal to the single-device batched pass
    # (order-insensitive reductions), moment-derived fields allclose only
    # (fp32 summation order differs across shard layouts), wa NaN.
    st0, dr1 = e_1d.init_sweep(deltas, replicas=R)
    _, sw1 = e_1d.run(st0, seed=5, n_steps=16, deltas=dr1)
    results["u_exact"] = bool(np.array_equal(
        np.asarray(sw.utilization), np.asarray(sw1.utilization)))
    results["gvt_exact"] = bool(np.array_equal(
        np.asarray(sw.gvt), np.asarray(sw1.gvt)))
    results["w2_close"] = bool(np.allclose(
        np.asarray(sw.w2), np.asarray(sw1.w2), rtol=1e-5, atol=1e-6))
    results["moments_close"] = all(bool(np.allclose(
        np.asarray(getattr(sw, f)), np.asarray(getattr(sw1, f)),
        rtol=1e-5, atol=1e-5)) for f in ("mean_tau", "max_dev", "min_dev"))
    results["wa_nan"] = bool(np.isnan(np.asarray(sw.wa)).all())

    # -- experiments-level: records parity, divisible grid ----------------
    spec = WindowSweep(Ls=(32,), n_vs=(4,), deltas=(1.0, 2.0, 4.0, math.inf),
                       replicas=3, n_steps=16, burn_in=8, backend="sharded",
                       k_fuse=4, seed=5)
    res_sh = run_window_sweep(spec, mesh=mesh)
    import dataclasses
    res_1d = run_window_sweep(dataclasses.replace(spec, backend="reference"))
    rec_ok = len(res_sh.records) == len(res_1d.records)
    for a, b in zip(res_sh.records, res_1d.records):
        rec_ok &= (a.L, a.n_v, a.delta) == (b.L, b.n_v, b.delta)
        rec_ok &= a.u == b.u and a.u_err == b.u_err
        rec_ok &= a.rate == b.rate and a.rate_err == b.rate_err
        rec_ok &= bool(np.isclose(a.w2, b.w2, rtol=1e-4, atol=1e-6))
        rec_ok &= math.isnan(a.wa) and not math.isnan(b.wa)
    results["records_parity"] = bool(rec_ok)

    # serial sharded loop (the benchmark baseline) gives the same records;
    # its replicas must divide the ensemble extent (2), hence a new spec
    spec_s = dataclasses.replace(spec, replicas=2)
    res_sb = run_window_sweep(spec_s, mesh=mesh)
    res_ser = serial_window_sweep(spec_s, mesh=mesh)
    ser_ok = all(
        a.u == b.u and a.rate == b.rate
        and bool(np.isclose(a.w2, b.w2, rtol=1e-5, atol=1e-6))
        for a, b in zip(res_sb.records, res_ser.records))
    results["serial_sharded_parity"] = bool(ser_ok)

    # -- ragged padding: 3 deltas x 1 replica = 3 rows on ens extent 2 ----
    spec_r = WindowSweep(Ls=(16,), n_vs=(2,), deltas=(1.0, 4.0, math.inf),
                         replicas=1, n_steps=8, burn_in=4, backend="sharded",
                         k_fuse=4, seed=9)
    (plan,) = plan_mesh_sweep(spec_r, mesh)
    results["ragged_plan"] = (plan.n_rows, plan.n_pad, plan.n_padded,
                              plan.ens_extent)
    res_r = run_window_sweep(spec_r, mesh=mesh)
    res_r1 = run_window_sweep(dataclasses.replace(spec_r,
                                                  backend="reference"))
    pad_ok = all(
        a.u == b.u and a.rate == b.rate
        and bool(np.isclose(a.w2, b.w2, rtol=1e-4, atol=1e-6))
        for a, b in zip(res_r.records, res_r1.records))
    results["ragged_purity"] = bool(pad_ok)

    # multi-grid-point trial_base bookkeeping stays aligned across padding
    spec_g = WindowSweep(Ls=(16, 32), n_vs=(2,), deltas=(2.0, math.inf),
                         replicas=1, n_steps=8, burn_in=4, backend="sharded",
                         k_fuse=4, seed=2)
    plans = plan_mesh_sweep(spec_g, mesh)
    results["grid_bases"] = [p.trial_base for p in plans]
    res_g = run_window_sweep(spec_g, mesh=mesh)
    res_g1 = run_window_sweep(dataclasses.replace(spec_g,
                                                  backend="reference"))
    results["grid_purity"] = bool(all(
        a.u == b.u and a.rate == b.rate
        for a, b in zip(res_g.records, res_g1.records)))

    print(json.dumps(results))
""")


@pytest.fixture(scope="module")
def sweep_results():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sweep_bit_identical_to_serial_loop(sweep_results):
    """The tentpole claim: on a 2x4 mesh, the batched sharded sweep's
    trajectories equal the single-device serial per-Δ loop bit-for-bit."""
    assert sweep_results["engine_bit_identity"]


def test_sweep_stats_contract(sweep_results):
    assert sweep_results["u_exact"]
    assert sweep_results["gvt_exact"]
    assert sweep_results["w2_close"]
    assert sweep_results["moments_close"]
    assert sweep_results["wa_nan"]


def test_sweep_records_match_single_device(sweep_results):
    assert sweep_results["records_parity"]


def test_serial_sharded_baseline_matches(sweep_results):
    assert sweep_results["serial_sharded_parity"]


def test_ragged_padding_does_not_contaminate(sweep_results):
    n_rows, n_pad, n_padded, ens = sweep_results["ragged_plan"]
    assert (n_rows, n_pad, n_padded, ens) == (3, 1, 4, 2)
    assert sweep_results["ragged_purity"]


def test_multi_grid_point_bases(sweep_results):
    assert sweep_results["grid_bases"] == [0, 2]
    assert sweep_results["grid_purity"]


# ---------------------------------------------------------------------------
# in-process scheduler tests (AbstractMesh: axis sizes only, no devices)
# ---------------------------------------------------------------------------


def _abstract_mesh(ens=2, ring=4):
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh((("data", ens), ("model", ring)))
    except TypeError:
        return AbstractMesh((ens, ring), ("data", "model"))


def test_plan_mesh_sweep_shapes():
    from repro.experiments.sweep import WindowSweep, plan_mesh_sweep
    spec = WindowSweep(Ls=(16, 32), n_vs=(1, 2), deltas=(1.0, math.inf),
                       replicas=3, n_steps=16, burn_in=10, backend="sharded",
                       k_fuse=4)
    plans = plan_mesh_sweep(spec, _abstract_mesh())
    assert len(plans) == 4
    assert [p.trial_base for p in plans] == [0, 6, 12, 18]
    for p in plans:
        assert p.n_rows == 6 and p.n_pad == 0
        assert p.ens_extent == 2 and p.ring_extent == 4
        assert p.burn_in == 12          # 10 rounded up to whole 4-chunks


def test_plan_mesh_sweep_ragged_and_errors():
    from repro.experiments.sweep import WindowSweep, plan_mesh_sweep
    spec = WindowSweep(Ls=(16,), n_vs=(1,), deltas=(1.0, 2.0, math.inf),
                       replicas=1, n_steps=8, burn_in=8, backend="sharded",
                       k_fuse=4)
    (p,) = plan_mesh_sweep(spec, _abstract_mesh())
    assert (p.n_rows, p.n_pad, p.n_padded) == (3, 1, 4)

    import dataclasses
    with pytest.raises(ValueError, match="divide L"):
        plan_mesh_sweep(dataclasses.replace(spec, Ls=(30,)),
                        _abstract_mesh())
    with pytest.raises(ValueError, match="whole chunks"):
        plan_mesh_sweep(dataclasses.replace(spec, n_steps=10),
                        _abstract_mesh())
    with pytest.raises(ValueError, match="axes"):
        from repro.core.distributed import DistConfig
        plan_mesh_sweep(spec, _abstract_mesh(),
                        DistConfig(ens_axes=("pod",)))


def test_run_window_sweep_mesh_arg_validation():
    from repro.experiments.sweep import (WindowSweep, run_window_sweep,
                                         serial_window_sweep)
    sharded = WindowSweep(backend="sharded", n_steps=16, burn_in=0, k_fuse=4)
    with pytest.raises(ValueError, match="mesh"):
        run_window_sweep(sharded)
    single = WindowSweep(backend="reference", n_steps=16, burn_in=0)
    with pytest.raises(ValueError, match="sharded"):
        run_window_sweep(single, mesh=_abstract_mesh())
    with pytest.raises(ValueError, match="sharded"):
        serial_window_sweep(single, mesh=_abstract_mesh())


def test_steady_state_sweep_rejects_unknown_opts():
    from repro.core.ensemble import steady_state_sweep
    from repro.core.horizon import PDESConfig
    cfg = PDESConfig(L=16, n_v=1, delta=math.inf)
    with pytest.raises(ValueError, match="engine_opts"):
        steady_state_sweep(cfg, (1.0,), n_trials=2, burn_in_steps=2,
                           measure_steps=4,
                           engine_opts={"interpret": False})

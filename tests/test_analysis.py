"""Causality-linter tests: golden reports + one negative test per rule.

Two halves:

* **golden** — ``analyze_backend`` on the clean tree must reproduce the
  committed ``tests/golden/analysis_<backend>.json`` byte-for-byte
  (structurally).  Regenerate after an intentional analyzer/backend change::

      PYTHONPATH=src python - <<'EOF'
      import json, pathlib
      from repro.analysis import analyze_backend
      from repro.core.engine import BACKENDS
      for b in BACKENDS:
          p = pathlib.Path("tests/golden") / f"analysis_{b}.json"
          p.write_text(json.dumps(analyze_backend(b).to_dict(),
                                  indent=2, sort_keys=True) + "\n")
      EOF

* **negative** — every rule is proven live by a seeded-violation fixture
  (``repro.analysis.fixtures``): a linter whose rules never fire proves
  nothing, so each fixture plants exactly one protocol violation and the
  test asserts the expected rule reports it.
"""
import json
import pathlib

import pytest

jax = pytest.importorskip("jax")

from repro.analysis import (ALL_RULES, analyze, analyze_backend,
                            analyze_probe)
from repro.analysis.fixtures import FIXTURES
from repro.core.engine import BACKENDS

GOLDEN = pathlib.Path(__file__).parent / "golden"

pytestmark = pytest.mark.analysis


# ---------------------------------------------------------------------------
# golden reports: the clean tree analyzes clean, and identically so
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
def test_golden_report(backend):
    got = analyze_backend(backend).to_dict()
    want = json.loads((GOLDEN / f"analysis_{backend}.json").read_text())
    assert got == want, (
        f"analysis report for {backend!r} drifted from the golden; if the "
        f"change is intentional, regenerate (see module docstring)")


@pytest.mark.parametrize("backend", BACKENDS)
def test_clean_tree_has_zero_findings(backend):
    rep = analyze_backend(backend)
    assert rep.ok, "\n".join(str(f) for f in rep.findings)
    assert sorted(set(rep.rules_run)) == sorted(ALL_RULES)


def test_sharded_sweep_probe_runs():
    """Multi-device sweep sharding landed: every backend (sharded included)
    yields a live sweep probe with a traced Δ-column operand."""
    from repro.analysis.probes import iter_probes
    sweeps = [p for p in iter_probes("sharded") if p.name == "sweep"]
    assert len(sweeps) == 1
    (p,) = sweeps
    assert p.delta_input is not None and p.delta == 0.0
    assert p.shard_L == {"model": 8}


@pytest.mark.parametrize("backend", BACKENDS)
def test_service_probe_traces_trial_vector(backend):
    """The coalesced-batch entry point (repro.service) is a first-class
    probe on every backend: per-row Δ column AND per-row trial-index vector
    are traced operands, so the protocol rules cover multiplexed passes."""
    from repro.analysis.probes import iter_probes
    probes = [p for p in iter_probes(backend) if p.name == "service"]
    assert len(probes) == 1
    (p,) = probes
    assert p.delta_input is not None
    assert p.trial_input is not None


# ---------------------------------------------------------------------------
# negative tests: each rule fires on its seeded-violation fixture
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_fixture_fires_expected_rule(name):
    probe, expected_rule = FIXTURES[name]()
    findings = analyze_probe(probe)
    fired = {f.rule for f in findings}
    assert expected_rule in fired, (
        f"fixture {name!r} should trip {expected_rule!r}; fired: "
        f"{sorted(fired)}")
    hits = [f for f in findings if f.rule == expected_rule]
    # findings carry context + provenance, not just a verdict
    assert all(f.backend == probe.backend and f.probe == probe.name
               for f in hits)
    assert any(f.op or f.path for f in hits), hits


@pytest.mark.parametrize("name", sorted(FIXTURES))
def test_fixture_clean_rules_stay_quiet(name):
    """A planted violation must not cascade into unrelated rules.

    (vmem_blowup is exempt for stencil/window: a whole-ring block trivially
    also breaks locality and drowns the guard pattern — that cascade is
    physical, not a false positive.)
    """
    probe, expected_rule = FIXTURES[name]()
    fired = {f.rule for f in analyze_probe(probe)}
    allowed = {expected_rule}
    if name == "vmem_blowup":
        allowed |= {"stencil-locality", "window-bound"}
    assert fired <= allowed, sorted(fired - allowed)


def test_waiver_keeps_finding_but_passes_gate():
    probe, rule = FIXTURES["decreasing_tau"]()
    from repro.analysis.report import BackendReport, apply_waivers
    findings = apply_waivers(analyze_probe(probe), (rule,))
    assert findings and all(f.waived for f in findings)
    rep = BackendReport(backend=probe.backend, findings=findings)
    assert rep.ok                       # waived findings don't fail the gate
    # a waiver scoped to a different backend does NOT apply
    findings = apply_waivers(analyze_probe(probe),
                             (f"{rule}:some_other_backend",))
    assert not BackendReport(backend=probe.backend, findings=findings).ok


def test_vmem_budget_is_configurable():
    # the clean pallas kernels fit the default budget but not 1 byte
    rep = analyze_backend("pallas", vmem_budget=1)
    assert not rep.ok
    assert {f.rule for f in rep.findings} == {"vmem-budget"}


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_json_roundtrip(tmp_path, capsys):
    from repro.analysis.__main__ import main
    out = tmp_path / "report.json"
    rc = main(["--backend", "reference", "--format", "json",
               "-o", str(out)])
    assert rc == 0
    printed = json.loads(capsys.readouterr().out)
    on_disk = json.loads(out.read_text())
    assert printed == on_disk
    assert on_disk["ok"] and on_disk["n_findings"] == 0
    assert [b["backend"] for b in on_disk["backends"]] == ["reference"]


def test_cli_rule_subset_and_unknown_args(capsys):
    from repro.analysis.__main__ import main
    rc = main(["--backend", "reference", "--rules", "vmem-budget"])
    assert rc == 0
    assert "rules=vmem-budget" in capsys.readouterr().out
    with pytest.raises(SystemExit):
        main(["--backend", "nope"])
    with pytest.raises(SystemExit):
        main(["--rules", "nope"])
    capsys.readouterr()


def test_full_report_shape():
    rep = analyze(backends="all")
    d = rep.to_dict()
    assert d["ok"] is True
    assert [b["backend"] for b in d["backends"]] == list(BACKENDS)
    # text rendering mentions every backend and the final verdict line
    txt = rep.to_text()
    for b in BACKENDS:
        assert f"backend={b}" in txt
    assert txt.splitlines()[-1].startswith("analysis: PASS")

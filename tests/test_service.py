"""Sweep-service tests: coalesced bit-identity, dedup, cache, scheduling.

The tentpole contract (``repro.service``): a coalesced device pass must
return, for every request, exactly the rows a direct ``run_window_sweep``
of that request's spec would return — float-equal records, not allclose.
The single-device gate runs in-process (three overlapping requests share
one pass); the sharded gate runs in one subprocess with 8 fake CPU devices
(same pattern as tests/test_sharded_sweep.py).  Around the gate: scheduler
units (compat keying, Δ-grid union packing, admission, Eq. (3) fairness),
the burned-state LRU, the wire schema + ``python -m repro.service`` CLI,
and the golden-section Δ* refiner that drives the service adaptively.
"""
import dataclasses
import json
import math
import os
import subprocess
import sys

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.experiments import (WindowSweep, refine_optimal_window,
                               optimal_windows, run_window_sweep)
from repro.experiments.sweep import spec_from_dict, spec_to_dict
from repro.service import (BatchScheduler, CompatKey, GridJob, StateCache,
                           SweepService, canonicalize_spec, decode_request,
                           decode_response, encode_request, encode_response,
                           spec_fingerprint, window_admission)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the shared single-device pass shape of the coalescing tests
COMMON = dict(Ls=(16,), n_vs=(2,), replicas=4, n_steps=32, burn_in=16,
              backend="pallas_multistep", k_fuse=8)


def _key(**kw) -> CompatKey:
    base = dict(L=16, n_v=2, backend="reference", window="exact", k_fuse=8,
                rd_mode=False, border_both=False, seed=0, burn=16, n_steps=32)
    base.update(kw)
    return CompatKey(**base)


def _job(requester, seq, rows, key=None) -> GridJob:
    deltas = tuple(dict.fromkeys(d for _, d in rows))
    return GridJob(fp=f"fp-{requester}-{seq}", requester=requester, seq=seq,
                   key=key or _key(), rows=tuple(rows), deltas=deltas,
                   replicas=len(rows) // len(deltas), steady_frac=0.5)


# ---------------------------------------------------------------------------
# Eq. (3) as an admission predicate + compat keying
# ---------------------------------------------------------------------------


def test_window_admission_is_eq3():
    # tau <= delta + gvt, exactly the moving-window rule
    assert window_admission(5.0, 2.0, 4.0) is True
    assert window_admission(6.0, 2.0, 4.0) is True      # boundary included
    assert window_admission(6.1, 2.0, 4.0) is False
    assert window_admission(10, math.inf, 0) is True    # inf disables
    out = window_admission(np.array([1.0, 6.0, 7.0]), 2.0, 4.0)
    assert out.tolist() == [True, True, False]


def test_compat_stream_key_drops_n_steps():
    a, b = _key(n_steps=32), _key(n_steps=64)
    assert a != b                      # cannot share a pass...
    assert a.stream_key == b.stream_key   # ...but share burned-in states


def test_canonicalize_and_fingerprint():
    s1 = WindowSweep(Ls=[16], n_vs=(2,), deltas=[2, 4.0], **{
        k: v for k, v in COMMON.items() if k not in ("Ls", "n_vs")})
    s2 = WindowSweep(Ls=(16,), n_vs=[2], deltas=(2.0, 4.0), **{
        k: v for k, v in COMMON.items() if k not in ("Ls", "n_vs")})
    assert canonicalize_spec(s1) == canonicalize_spec(s2)
    assert spec_fingerprint(s1) == spec_fingerprint(s2)
    s3 = dataclasses.replace(s2, seed=1)
    assert spec_fingerprint(s3) != spec_fingerprint(s2)


def test_request_id_is_deterministic_and_idempotent():
    svc = SweepService()
    spec = WindowSweep(deltas=(2.0, 4.0), **COMMON)
    r1 = svc.submit(spec, requester="alice")
    r2 = svc.submit(spec, requester="alice")   # resubmission: same request
    r3 = svc.submit(spec, requester="bob")
    assert r1.request_id == r2.request_id
    assert r1.request_id != r3.request_id
    assert r1.fingerprint == r3.fingerprint    # same computation though
    assert svc.stats.n_requests == 2           # resubmission not re-counted


# ---------------------------------------------------------------------------
# scheduler: union packing, admission control, fairness
# ---------------------------------------------------------------------------


def test_pack_unions_shared_rows_and_slices_per_job():
    sched = BatchScheduler()
    a = _job("alice", 0, [(0, 2.0), (1, 2.0), (0, 4.0), (1, 4.0)])
    b = _job("bob", 1, [(0, 4.0), (1, 4.0), (0, 8.0), (1, 8.0)])
    sched.enqueue(a)
    sched.enqueue(b)
    (p,) = sched.take(force=True)
    assert sched.n_pending == 0
    # shared (trial, 4.0) rows computed once: 4 + 4 - 2 union rows
    assert p.n_rows == 6
    for job, cols in zip(p.jobs, p.cols):
        assert tuple(p.rows[c] for c in cols) == job.rows


def test_incompatible_keys_never_share_a_pass():
    sched = BatchScheduler()
    sched.enqueue(_job("alice", 0, [(0, 2.0)], key=_key(n_steps=32)))
    sched.enqueue(_job("bob", 1, [(0, 2.0)], key=_key(n_steps=64)))
    passes = sched.take(force=True)
    assert len(passes) == 2
    assert {p.key.n_steps for p in passes} == {32, 64}


def test_max_batch_rows_splits_job_granularly():
    sched = BatchScheduler(max_batch_rows=3)
    sched.enqueue(_job("a", 0, [(0, 1.0), (1, 1.0)]))
    sched.enqueue(_job("b", 1, [(2, 1.0), (3, 1.0)]))
    passes = sched.take(force=True)
    assert [p.n_rows for p in passes] == [2, 2]


def test_max_wait_rounds_holds_then_releases():
    sched = BatchScheduler(max_wait_rounds=2)
    sched.enqueue(_job("a", 0, [(0, 1.0)]))
    assert sched.take() == []          # round 1: held, accumulating
    assert sched.take() == []          # round 2: held
    assert len(sched.take()) == 1      # waited out: released
    sched.enqueue(_job("a", 1, [(0, 1.0)]))
    assert len(sched.take(force=True)) == 1   # force overrides the wait


def test_fairness_window_throttles_served_requesters():
    sched = BatchScheduler(fairness_rows=4)
    sched.enqueue(_job("greedy", 0, [(0, 1.0)]))
    sched.enqueue(_job("starved", 1, [(1, 1.0)]))
    served = {"greedy": 10, "starved": 0}   # gvt = 0, window = 4
    (p,) = sched.take(served)
    assert [j.requester for j in p.jobs] == ["starved"]
    (p,) = sched.take(served, force=True)   # drain serves everyone
    assert [j.requester for j in p.jobs] == ["greedy"]


# ---------------------------------------------------------------------------
# burned-state LRU
# ---------------------------------------------------------------------------


def test_state_cache_lru_and_counters():
    cache = StateCache(max_rows=2)
    tau = np.zeros(4, np.float32)
    cache.put("a", tau, 0.0, 0.0)
    cache.put("b", tau, 1.0, 0.0)
    assert cache.get("a") is not None   # refreshes a
    cache.put("c", tau, 2.0, 0.0)       # evicts b (LRU)
    assert cache.get("b") is None
    assert cache.get("a") is not None and cache.get("c") is not None
    assert cache.misses == 1 and cache.hits == 3


# ---------------------------------------------------------------------------
# the bit-identity gate: coalesced == direct, float-equal
# ---------------------------------------------------------------------------


def test_coalesced_pass_bit_identical_to_direct_runs():
    """Three overlapping requests share one device pass; every response is
    float-equal to a standalone ``run_window_sweep`` of its spec."""
    specs = {
        "alice": WindowSweep(deltas=(2.0, 4.0, math.inf), **COMMON),
        "bob": WindowSweep(deltas=(2.0, 4.0), **COMMON),
        "carol": WindowSweep(deltas=(1.0, 4.0, 8.0), **COMMON),
    }
    svc = SweepService()
    for who, spec in specs.items():
        svc.submit(spec, requester=who)
    responses = svc.drain()
    assert svc.stats.n_passes == 1          # one coalesced pass served all
    assert svc.stats.rows_computed < sum(
        s.n_trajectories for s in specs.values())   # shared rows dedup'd
    for resp in responses:
        direct = run_window_sweep(resp.spec)
        assert resp.result.records == direct.records, resp.requester


def test_dedup_identical_specs_no_recompute():
    spec = WindowSweep(deltas=(2.0, 4.0), **COMMON)
    svc = SweepService()
    svc.submit(spec, requester="alice")
    svc.submit(spec, requester="bob")       # in-flight dedup
    r1, r2 = svc.drain()
    assert not r1.cached and r2.cached
    assert r1.result.records == r2.result.records
    assert svc.stats.n_passes == 1
    assert svc.stats.rows_computed == spec.n_trajectories
    svc.submit(spec, requester="carol")     # post-drain dedup: result cache
    (r3,) = svc.drain()
    assert r3.cached and r3.result.records == r1.result.records
    assert svc.stats.n_passes == 1          # still exactly one pass ever
    assert svc.stats.n_deduped == 2


def test_state_cache_reuse_does_not_perturb_results():
    """A later request sharing the stream prefix pulls burned-in rows from
    the cache; its records stay bit-identical to an uncached direct run."""
    first = WindowSweep(deltas=(2.0, 4.0), **COMMON)
    longer = dataclasses.replace(first, n_steps=64)
    svc = SweepService()
    svc.submit(first, requester="alice")
    svc.drain()
    assert svc.stats.rows_from_state_cache == 0
    svc.submit(longer, requester="alice")
    (resp,) = svc.drain()
    assert svc.stats.rows_from_state_cache == first.n_trajectories
    direct = run_window_sweep(longer)
    assert resp.result.records == direct.records


def test_partial_state_cache_overlap_bit_identical():
    """A pass mixing cached and freshly-burned rows (the splice path in
    ``_burned_state``) still reproduces the direct run exactly."""
    svc = SweepService()
    svc.submit(WindowSweep(deltas=(2.0,), **COMMON), requester="alice")
    svc.drain()
    mixed = WindowSweep(deltas=(2.0, 8.0), **COMMON)   # one Δ cached, one not
    svc.submit(mixed, requester="alice")
    (resp,) = svc.drain()
    assert 0 < svc.stats.rows_from_state_cache < mixed.n_trajectories
    assert resp.result.records == run_window_sweep(mixed).records


# ---------------------------------------------------------------------------
# sharded gate: coalesced mesh pass == direct sharded sweep (subprocess)
# ---------------------------------------------------------------------------

SHARDED_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, math
import numpy as np
import jax
from repro.compat import make_mesh
from repro.experiments.sweep import WindowSweep, run_window_sweep
from repro.service import SweepService

def rec_eq(a, b):
    da, db = dataclasses.asdict(a), dataclasses.asdict(b)
    return all(v == db[k] or (isinstance(v, float) and math.isnan(v)
                              and math.isnan(db[k]))
               for k, v in da.items())

results = {}
mesh = make_mesh((2, 4), ("data", "model"))
common = dict(Ls=(16,), n_vs=(2,), replicas=3, n_steps=32, burn_in=16,
              backend="sharded")
specs = {"alice": WindowSweep(deltas=(2.0, 4.0, math.inf), **common),
         "bob": WindowSweep(deltas=(4.0, 8.0), **common),
         "carol": WindowSweep(deltas=(2.0, 8.0, math.inf), **common)}
svc = SweepService(mesh=mesh)
for who, spec in specs.items():
    svc.submit(spec, requester=who)
for resp in svc.drain():
    direct = run_window_sweep(resp.spec, mesh=mesh)
    results[resp.requester] = all(
        rec_eq(x, y) for x, y in zip(resp.result.records, direct.records))
results["one_pass"] = svc.stats.n_passes == 1

# ragged union (3 requesters x shared rows) padded to the ens extent, and a
# follow-up with longer n_steps served from the burned-state cache
follow = dataclasses.replace(specs["bob"], n_steps=48)
svc.submit(follow, requester="bob")
(r2,) = svc.drain()
d2 = run_window_sweep(follow, mesh=mesh)
results["cache_follow"] = all(
    rec_eq(x, y) for x, y in zip(r2.result.records, d2.records))
results["cache_hits"] = svc.stats.rows_from_state_cache > 0
print(json.dumps(results))
"""


@pytest.mark.distributed
def test_sharded_coalesced_bit_identity():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT],
                         capture_output=True, text=True, env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    results = json.loads(out.stdout.strip().splitlines()[-1])
    assert results == {k: True for k in results}, results


# ---------------------------------------------------------------------------
# wire schema + CLI
# ---------------------------------------------------------------------------


def test_wire_request_round_trip():
    spec = WindowSweep(deltas=(2.0, math.inf), **COMMON)
    obj = json.loads(json.dumps(encode_request(spec, "alice")))
    spec2, who = decode_request(obj)
    assert who == "alice" and spec2 == canonicalize_spec(spec)
    assert spec_to_dict(spec2)["deltas"] == [2.0, "inf"]
    assert spec_from_dict(spec_to_dict(spec2)) == spec2
    with pytest.raises(ValueError, match="schema version"):
        decode_request({**obj, "version": 99})


def test_wire_response_round_trip():
    spec = WindowSweep(deltas=(2.0,), **COMMON)
    svc = SweepService()
    svc.submit(spec, requester="alice")
    (resp,) = svc.drain()
    obj = json.loads(json.dumps(encode_response(resp)))
    back = decode_response(obj)
    assert back.request_id == resp.request_id
    assert back.result.records == resp.result.records
    assert not back.cached


def test_cli_drains_example_queue(tmp_path):
    queue = os.path.join(REPO, "examples", "service_queue.jsonl")
    out_path = tmp_path / "responses.jsonl"
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.service", queue, "--out",
         str(out_path)],
        capture_output=True, text=True, env=env, cwd=REPO)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "1 deduped" in out.stderr and "1 coalesced pass" in out.stderr
    lines = out_path.read_text().strip().splitlines()
    requests = [json.loads(li) for li in
                open(queue).read().strip().splitlines()]
    assert len(lines) == len(requests) == 3
    responses = [decode_response(json.loads(li)) for li in lines]
    # responses come back in queue order with the queue's requester names
    assert [r.requester for r in responses] == [
        r["requester"] for r in requests]
    # alice and carol queued the identical spec: dedup'd, equal records
    assert responses[2].cached and not responses[0].cached
    assert responses[0].result.records == responses[2].result.records


# ---------------------------------------------------------------------------
# adaptive Δ* refinement through the service
# ---------------------------------------------------------------------------


def test_refiner_matches_dense_grid_with_fewer_engine_steps():
    common = dict(Ls=(32,), n_vs=(2,), replicas=6, n_steps=32, burn_in=32,
                  backend="pallas_multistep", k_fuse=8)
    coarse = WindowSweep(deltas=(0.5, 1.0, 2.0, 4.0, 8.0), **common)
    svc = SweepService()
    ref = refine_optimal_window(coarse, rounds=3, service=svc)
    assert ref.interior                      # the paper's claim: Δ* interior
    assert ref.bracket[0] <= ref.delta_star <= ref.bracket[1]
    # the polish round re-measured the winner off cached burned-in rows
    assert svc.stats.rows_from_state_cache > 0

    dense_deltas = tuple(float(x) for x in
                         np.round(np.linspace(0.5, 8.0, 12), 4))
    svc2 = SweepService()
    svc2.submit(WindowSweep(deltas=dense_deltas, **common), "grid")
    opt = optimal_windows(svc2.drain()[0].result)[0]
    spacing = dense_deltas[1] - dense_deltas[0]
    assert abs(ref.delta_star - opt.delta_star) <= 1.5 * spacing
    assert svc.stats.engine_row_steps < svc2.stats.engine_row_steps


def test_refiner_coalesces_probes_and_handles_boundary():
    common = dict(Ls=(16,), n_vs=(2,), replicas=4, n_steps=32, burn_in=16,
                  backend="pallas_multistep", k_fuse=8)
    svc = SweepService()
    ref = refine_optimal_window(WindowSweep(deltas=(1.0, 2.0, 4.0), **common),
                                rounds=2, service=svc)
    # the coarse round coalesced its three single-Δ probes into one pass
    assert svc.stats.n_passes < svc.stats.n_requests
    assert all(math.isfinite(e) for _, e in ref.evaluations)
    if not ref.interior:
        # boundary argmax: no golden-section rounds, coarse winner polished
        assert ref.rounds == 0
        assert ref.delta_star in (1.0, 4.0)
    else:
        assert len(ref.evaluations) >= 3 + 2

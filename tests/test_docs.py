"""Documentation layer: link integrity + content freshness.

The CI docs job runs ``tools/check_docs.py`` directly; these tests run the
same checker in-process (so `pytest` alone catches doc rot) and pin the
facts the documents state to the code they describe — backend matrix,
tier-1 command, bench names — so the docs can't silently drift from the
tree.
"""
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "tools"))

import check_docs  # noqa: E402


def test_docs_links_and_anchors_resolve(monkeypatch):
    monkeypatch.chdir(ROOT)
    assert check_docs.check(["README.md", "docs"]) == []


def test_checker_catches_breakage(tmp_path):
    (tmp_path / "a.md").write_text("# A\n[dead](missing.md)\n")
    probs = check_docs.check([str(tmp_path)])
    assert any("broken link" in p for p in probs)
    (tmp_path / "a.md").write_text("# A\n[b](b.md#nope)\n")
    (tmp_path / "b.md").write_text("# Real Heading\n")
    probs = check_docs.check([str(tmp_path)])
    assert any("broken anchor" in p for p in probs)
    (tmp_path / "c.md").write_text("# C — linked by nobody\n")
    probs = check_docs.check([str(tmp_path)])
    assert any("orphan" in p and "c.md" in p for p in probs)


def test_github_slug_convention():
    assert check_docs.github_slug("The analysis linter") == \
        "the-analysis-linter"
    assert check_docs.github_slug("Install / `[test]` extras") == \
        "install--test-extras"


def test_readme_backend_matrix_is_current():
    from repro.core.engine import BACKENDS
    readme = (ROOT / "README.md").read_text()
    for b in BACKENDS:
        assert f"`{b}`" in readme, f"README backend matrix lacks {b!r}"


def test_readme_states_the_tier1_command():
    readme = (ROOT / "README.md").read_text()
    assert "PYTHONPATH=src python -m pytest -x -q" in readme
    roadmap = (ROOT / "ROADMAP.md").read_text()
    m = re.search(r"\*\*Tier-1 verify:\*\* `([^`]+)`", roadmap)
    assert m, "ROADMAP lost its tier-1 verify line"
    # README quotes the same core command ROADMAP declares authoritative
    assert "python -m pytest -x -q" in m.group(1)


def test_readme_names_the_gated_benches():
    sys.path.insert(0, str(ROOT))
    from benchmarks.run import BENCHES
    readme = (ROOT / "README.md").read_text()
    for name in ("kernel_fused", "window_sweep", "window_sweep_sharded",
                 "sweep_service", "pdes_comm"):
        assert name in BENCHES
        assert name in readme, f"README bench list lacks {name!r}"


def test_architecture_names_every_core_module():
    doc = (ROOT / "docs" / "architecture.md").read_text()
    for mod in ("events", "horizon", "kernels", "engine", "distributed",
                "experiments", "analysis"):
        assert mod in doc
    # the sweep dataflow section reflects the real entry points
    for fn in ("init_sweep", "run_sharded_state", "plan_mesh_sweep",
               "sweep_reduce", "serial_window_sweep"):
        assert fn in doc, f"architecture.md sweep dataflow lacks {fn}"


def test_paper_map_rows_point_at_real_files():
    doc = (ROOT / "docs" / "paper_map.md").read_text()
    for path in re.findall(r"`(tests/[\w./]+\.py)`", doc):
        assert (ROOT / path).exists(), f"paper_map.md references {path}"
    # benchmarks/run.py::name references must be registered benches
    sys.path.insert(0, str(ROOT))
    from benchmarks.run import BENCHES
    for name in re.findall(r"benchmarks/run\.py::(\w+)", doc):
        for n in name.split("/"):
            assert n in BENCHES, f"paper_map.md references bench {n!r}"


def test_stale_sweep_docs_are_gone():
    """PR guard: no doc/docstring still claims sharded sweeps are
    unsupported or that the analysis sweep probe is skipped."""
    engine_doc = (ROOT / "src/repro/core/engine.py").read_text()
    assert "UnsupportedSweepError" not in engine_doc
    assert "check_sweep_support" not in engine_doc
    tests_readme = (ROOT / "tests" / "README.md").read_text()
    assert "skipped-with-reason" not in tests_readme

"""Sharded PDES equivalence (runs in a subprocess with 8 fake devices,
since the main pytest process must keep the default 1-device platform)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.distributed

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, math
    import jax, numpy as np
    from repro.compat import make_mesh
    from repro.core.horizon import PDESConfig
    from repro.core import distributed as D

    results = {}
    mesh = make_mesh((2, 4), ("data", "model"))
    for (delta, nv, mode, K) in [(5.0, 1, "exact", 8),
                                 (math.inf, 1, "exact", 8),
                                 (5.0, 10, "commavoid", 4),
                                 (10.0, 3, "commavoid", 8)]:
        cfg = PDESConfig(L=32, n_v=nv, delta=delta)
        dist = D.DistConfig(ens_axes=("data",), ring_axis="model",
                            mode=mode, k_chunk=K)
        tau_s, st_s = D.run_sharded(cfg, mesh, n_trials=6, n_steps=24,
                                    seed=7, dist=dist)
        stale = None if mode == "exact" else K
        tau_r, st_r = D.run_reference(cfg, n_trials=6, n_steps=24, seed=7,
                                      stale_every=stale)
        err_tau = float(np.max(np.abs(np.asarray(tau_s) - np.asarray(tau_r))))
        err_u = float(np.max(np.abs(np.asarray(st_s["u"]) - np.asarray(st_r["u"]))))
        results[f"{mode}_{delta}_{nv}_{K}"] = {"tau": err_tau, "u": err_u}

    # multipod ensemble axes
    mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
    dist3 = D.DistConfig(ens_axes=("pod", "data"), ring_axis="model",
                         mode="exact", k_chunk=4)
    cfg3 = PDESConfig(L=16, n_v=2, delta=3.0)
    tau_s, _ = D.run_sharded(cfg3, mesh3, n_trials=8, n_steps=12, seed=2,
                             dist=dist3)
    tau_r, _ = D.run_reference(cfg3, n_trials=8, n_steps=12, seed=2)
    results["multipod"] = {
        "tau": float(np.max(np.abs(np.asarray(tau_s) - np.asarray(tau_r)))),
        "u": 0.0}
    print(json.dumps(results))
""")


@pytest.fixture(scope="module")
def sharded_results():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_exact_mode_matches_reference(sharded_results):
    for k, v in sharded_results.items():
        if k.startswith("exact"):
            assert v["tau"] < 1e-4 and v["u"] < 1e-6, (k, v)


def test_commavoid_mode_matches_reference(sharded_results):
    for k, v in sharded_results.items():
        if k.startswith("commavoid"):
            assert v["tau"] < 1e-4 and v["u"] < 1e-6, (k, v)


def test_multipod_ensemble_axes(sharded_results):
    assert sharded_results["multipod"]["tau"] < 1e-4


def test_stale_gvt_is_conservative():
    """Stale window ⊆ exact window: commavoid may only reduce utilization,
    and never violates the Δ bound (measured on the reference impl)."""
    import numpy as np
    from repro.core import distributed as D
    from repro.core.horizon import PDESConfig
    cfg = PDESConfig(L=64, n_v=1, delta=4.0)
    tau_e, st_e = D.run_reference(cfg, n_trials=16, n_steps=300, seed=1)
    tau_c, st_c = D.run_reference(cfg, n_trials=16, n_steps=300, seed=1,
                                  stale_every=8)
    u_e = np.asarray(st_e["u"])[100:].mean()
    u_c = np.asarray(st_c["u"])[100:].mean()
    assert u_c <= u_e + 0.01
    # window invariant holds throughout for the stale variant as well
    spread = np.asarray(tau_c).max(-1) - np.asarray(tau_c).min(-1)
    assert (spread <= cfg.delta + 14.0).all()

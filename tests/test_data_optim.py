"""Optimizer, schedule, gradient compression, prefetcher."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import DataConfig, Prefetcher, make_batch
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig, lr_schedule
from repro.optim.grad import (dequantize, ef_compress_leaf, init_error_state,
                              quantize_int8)


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(peak_lr=0.1, warmup_steps=5, total_steps=200,
                          weight_decay=0.0, clip_norm=100.0)
        target = jnp.array([1.0, -2.0, 3.0])
        params = {"w": jnp.zeros(3)}
        state = adamw.init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
            params, state, m = adamw.update(g, state, params, cfg)
        np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                                   atol=0.05)

    def test_clip_norm(self):
        cfg = AdamWConfig(clip_norm=1.0)
        params = {"w": jnp.zeros(4)}
        state = adamw.init(params)
        g = {"w": jnp.full(4, 100.0)}
        _, _, m = adamw.update(g, state, params, cfg)
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_no_decay_on_norm_scales(self):
        cfg = AdamWConfig(peak_lr=1.0, warmup_steps=0, weight_decay=0.1)
        params = {"mlp": {"wi": jnp.ones((2, 2))},
                  "ln": {"scale": jnp.ones(2)}}
        state = adamw.init(params)
        zg = jax.tree.map(jnp.zeros_like, params)
        new, _, _ = adamw.update(zg, state, params, cfg)
        # decayed matrix shrinks toward zero; norm scale untouched
        assert float(new["mlp"]["wi"].max()) < 1.0
        assert float(new["mlp"]["wi"].min()) > 0.5
        np.testing.assert_allclose(np.asarray(new["ln"]["scale"]), 1.0)

    def test_schedule_shape(self):
        cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(0, 101, 10)]
        assert lrs[1] == pytest.approx(1.0, rel=1e-3)      # end of warmup
        assert lrs[-1] == pytest.approx(0.1, rel=1e-2)     # cosine floor
        assert max(lrs) <= 1.0 + 1e-6


class TestCompression:
    def test_quantize_roundtrip_bound(self):
        x = jax.random.normal(jax.random.key(0), (1000,))
        q, s = quantize_int8(x)
        err = np.abs(np.asarray(dequantize(q, s) - x))
        assert err.max() <= float(s) * 0.5 + 1e-6

    def test_error_feedback_unbiased_over_time(self):
        """Constant gradient: EF-compressed sum converges to the true sum."""
        g = jax.random.normal(jax.random.key(1), (256,)) * 0.01
        err = jnp.zeros(256)
        total = jnp.zeros(256)
        for _ in range(50):
            q, s, err = ef_compress_leaf(g, err)
            total = total + dequantize(q, s)
        np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                                   atol=5e-4)

    def test_init_error_state(self):
        grads = {"a": jnp.ones((2, 3), jnp.bfloat16)}
        e = init_error_state(grads)
        assert e["a"].dtype == jnp.float32 and e["a"].shape == (2, 3)


class TestPipeline:
    def test_prefetcher_yields_in_order(self):
        dc = DataConfig(vocab_size=64, seq_len=8, global_batch=2)
        pf = Prefetcher(dc, start_step=0, depth=2)
        try:
            b0 = next(pf)
            b1 = next(pf)
            np.testing.assert_array_equal(np.asarray(b0["tokens"]),
                                          np.asarray(make_batch(dc, 0)["tokens"]))
            np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                          np.asarray(make_batch(dc, 1)["tokens"]))
        finally:
            pf.close()

"""Failure-path tests for the hardened sweep service (PR 9).

The hardening contract, exercised end to end:

* **wire v2** — structured ``error`` responses round-trip, v1 documents
  still decode, and intake (``read_queue``/``serve_queue``) degrades
  per-line: one malformed / oversized / wrong-version line gets an error
  response at its queue position, everything else is still served.
* **streaming flush** — completed responses reach the output sink before
  later passes run, so a mid-drain crash keeps finished work on disk.
* **engine failures** — a failing device pass is retried with capped
  backoff, then reported as a per-request ``engine`` error; other
  requests are unaffected and the fingerprint retries from scratch on
  resubmission.
* **persistence** — the burned-state cache survives processes
  (save/load round trip, corruption → cold start, version gating) and a
  daemon killed mid-queue resumes from it with responses bit-identical
  to direct runs (the PR's acceptance gate, run as real subprocesses).
* **quotas** — a flooding requester is metered per round while the
  fairness window keeps serving the laggard.
* **SIGTERM** — the daemon flushes every accepted request and exits 0.
"""
import dataclasses
import io
import json
import math
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.experiments import WindowSweep, run_window_sweep
from repro.experiments.sweep import SweepRecord, SweepResult
from repro.service import (CACHE_FORMAT_VERSION, BatchScheduler, CompatKey,
                           GridJob, QueueItem, StateCache, SweepResponse,
                           SweepService, WireError, canonicalize_spec,
                           decode_response, encode_error, encode_request,
                           encode_response, read_queue, serve_queue)
from repro.service import state_cache as state_cache_mod

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the shared fast pass shape of the service tests (8 rows, tiny ring)
COMMON = dict(Ls=(16,), n_vs=(2,), replicas=4, n_steps=32, burn_in=16,
              backend="pallas_multistep", k_fuse=8)


def _subproc_env():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    env.pop("XLA_FLAGS", None)
    return env


def _key(**kw) -> CompatKey:
    base = dict(L=16, n_v=2, backend="reference", window="exact", k_fuse=8,
                rd_mode=False, border_both=False, seed=0, burn=16, n_steps=32)
    base.update(kw)
    return CompatKey(**base)


def _job(requester, seq, rows) -> GridJob:
    deltas = tuple(dict.fromkeys(d for _, d in rows))
    return GridJob(fp=f"fp-{requester}-{seq}", requester=requester, seq=seq,
                   key=_key(), rows=tuple(rows), deltas=deltas,
                   replicas=len(rows) // len(deltas), steady_frac=0.5)


# ---------------------------------------------------------------------------
# wire schema v2: structured errors, v1 back-compat
# ---------------------------------------------------------------------------


def test_wire_error_response_round_trip():
    err = WireError("parse", "not valid JSON: boom", lineno=7,
                    requester="alice")
    obj = json.loads(json.dumps(encode_error(err)))
    assert obj["request_id"] == "line-7"      # intake errors have no rid
    resp = decode_response(obj)
    assert resp.result is None and resp.spec is None
    assert resp.error == {"code": "parse", "message": "not valid JSON: boom",
                          "lineno": 7}
    assert resp.requester == "alice"


def test_wire_v1_documents_still_decode():
    spec = canonicalize_spec(WindowSweep(deltas=(2.0, math.inf), **COMMON))
    from repro.experiments.sweep import spec_to_dict
    from repro.service import decode_request
    v1_req = {"version": 1, "requester": "bob",
              "spec": spec_to_dict(spec)}
    spec2, who = decode_request(v1_req)
    assert spec2 == spec and who == "bob"
    rec = SweepRecord(L=16, n_v=2, delta=2.0, u=1.0, u_err=0.0, w2=1.0,
                      w2_err=0.0, w=1.0, wa=1.0, spread=0.0, rate=0.5,
                      rate_err=0.0)
    resp = SweepResponse(request_id="ab12", requester="bob", spec=spec,
                         result=SweepResult(spec=spec, records=(rec,)),
                         cached=False)
    v1_resp = {**encode_response(resp), "version": 1}   # v1 writer: no error
    back = decode_response(v1_resp)
    assert back.result.records == resp.result.records
    with pytest.raises(ValueError, match="schema version"):
        decode_response({**v1_resp, "version": 99})


def test_read_queue_is_lazy_and_degrades_per_line(tmp_path):
    good = json.dumps(encode_request(WindowSweep(deltas=(2.0,), **COMMON),
                                     "alice"))
    queue = tmp_path / "q.jsonl"
    queue.write_text("\n".join([
        good,                                    # 1: fine
        "",                                      # 2: blank, skipped
        "{not json",                             # 3: parse error
        '{"version": 99, "spec": {}}',           # 4: version error
        '{"version": 2, "spec": {"Ls": "nope"}}',  # 5: schema error
        good,                                    # 6: fine again
    ]) + "\n")
    items = read_queue(queue)
    assert iter(items) is items                  # a generator, not a list
    items = list(items)
    assert [i.lineno for i in items] == [1, 3, 4, 5, 6]
    assert isinstance(items[0], QueueItem)
    assert items[0].error is None and items[0].requester == "alice"
    assert [i.error.code if i.error else None for i in items] == [
        None, "parse", "version", "schema", None]

    (only,) = [i for i in read_queue(queue, max_line_bytes=16)
               if i.lineno == 1]
    assert only.error.code == "oversize"


def test_serve_queue_recovers_from_malformed_lines(tmp_path):
    spec = WindowSweep(deltas=(2.0,), **COMMON)
    good = json.dumps(encode_request(spec, "alice"))
    queue = tmp_path / "q.jsonl"
    queue.write_text("\n".join([
        good, "{broken", '{"version": 99, "spec": {}}',
        json.dumps(encode_request(spec, "bob")),
    ]) + "\n")
    out = io.StringIO()
    stats = serve_queue(queue, out, service=SweepService())
    lines = out.getvalue().strip().splitlines()
    assert len(lines) == 4                       # one response per line
    responses = [decode_response(json.loads(li)) for li in lines]
    # errors sit at their queue positions; the drain still served the rest
    assert [r.error["code"] if r.error else None for r in responses] == [
        None, "parse", "version", None]
    assert responses[3].cached                   # bob dedup'd onto alice
    direct = run_window_sweep(spec)
    assert responses[0].result.records == direct.records
    assert responses[3].result.records == direct.records
    assert stats.n_errors == 2 and stats.n_requests == 2


def test_serve_queue_rejects_sharded_spec_without_mesh(tmp_path):
    sharded = dataclasses.replace(WindowSweep(deltas=(2.0,), **COMMON),
                                  backend="sharded")
    queue = tmp_path / "q.jsonl"
    queue.write_text(json.dumps(encode_request(sharded, "alice")) + "\n" +
                     json.dumps(encode_request(
                         WindowSweep(deltas=(2.0,), **COMMON), "bob")) + "\n")
    out = io.StringIO()
    serve_queue(queue, out, service=SweepService())   # mesh=None
    bad, ok = [decode_response(json.loads(li))
               for li in out.getvalue().strip().splitlines()]
    assert bad.error["code"] == "reject" and "mesh" in bad.error["message"]
    assert ok.error is None and ok.result is not None


def test_serve_queue_streams_responses_between_passes(tmp_path):
    """A finished response is flushed before the *next* pass runs — the
    crash-tolerance mechanism: killing the drain between passes loses only
    unfinished work."""
    spec1 = WindowSweep(deltas=(2.0,), **COMMON)
    spec2 = dataclasses.replace(spec1, n_steps=64)   # incompatible: 2 passes
    queue = tmp_path / "q.jsonl"
    queue.write_text(json.dumps(encode_request(spec1, "alice")) + "\n" +
                     json.dumps(encode_request(spec2, "bob")) + "\n")
    out = io.StringIO()
    svc = SweepService()
    flushed_before = []
    orig = svc._execute

    def spy(p):
        flushed_before.append(out.getvalue().count("\n"))
        orig(p)

    svc._execute = spy
    serve_queue(queue, out, service=svc)
    # pass 1 starts with nothing written; pass 2 starts with alice on disk
    assert flushed_before == [0, 1]
    assert out.getvalue().count("\n") == 2


# ---------------------------------------------------------------------------
# engine failures: retried, then scoped to the request
# ---------------------------------------------------------------------------


def test_engine_failure_retried_then_reported_per_request():
    good = WindowSweep(deltas=(2.0,), **COMMON)
    bad = dataclasses.replace(good, n_steps=64)
    svc = SweepService(engine_retries=2, retry_base_s=0.0)
    orig = svc._execute

    def flaky(p):
        if p.key.n_steps == 64:
            raise RuntimeError("device melted")
        orig(p)

    svc._execute = flaky
    svc.submit(good, requester="alice")
    svc.submit(bad, requester="bob")
    r_alice, r_bob = svc.drain()
    # alice is untouched by bob's failure — bit-identical to a direct run
    assert r_alice.error is None
    assert r_alice.result.records == run_window_sweep(good).records
    assert r_bob.result is None and r_bob.error["code"] == "engine"
    assert "device melted" in r_bob.error["message"]
    assert svc.stats.n_retries == 2              # capped-backoff attempts
    assert svc.stats.n_errors == 1

    # a failed fingerprint retries from scratch on resubmission
    svc._execute = orig
    svc.submit(bad, requester="bob")
    (r2,) = svc.drain()
    assert r2.error is None
    assert r2.result.records == run_window_sweep(bad).records


# ---------------------------------------------------------------------------
# per-round requester quotas on top of the Eq. (3) fairness window
# ---------------------------------------------------------------------------


def test_quota_meters_flooder_while_laggard_is_served_first():
    sched = BatchScheduler(fairness_rows=4, quota_rows=4)
    for i in range(8):                            # flooder: 16 rows queued
        sched.enqueue(_job("flood", i, [(2 * i, 1.0), (2 * i + 1, 1.0)]))
    sched.enqueue(_job("lag", 99, [(100, 1.0)]))  # laggard: 1 row
    served, rounds = {}, []
    while sched.n_pending:
        active = sched.pending_requesters
        view = {r: n for r, n in served.items() if r in active}
        got = {}
        for p in sched.take(view):
            for j in p.jobs:
                got[j.requester] = got.get(j.requester, 0) + len(j.rows)
                served[j.requester] = served.get(j.requester, 0) + len(j.rows)
        rounds.append(got)
        assert len(rounds) < 32, "quota starved the queue (livelock)"
    # the laggard is served in round 1, despite 8 queued flooder jobs ahead
    assert rounds[0].get("lag") == 1
    # the flooder never exceeds quota_rows per round and needs >= 4 rounds
    assert all(g.get("flood", 0) <= 4 for g in rounds)
    assert len(rounds) >= 4 and served == {"flood": 16, "lag": 1}


def test_quota_never_deadlocks_an_oversized_first_job():
    sched = BatchScheduler(quota_rows=1)
    sched.enqueue(_job("a", 0, [(0, 1.0), (1, 1.0)]))   # 2 rows > quota
    (p,) = sched.take()                 # still released: first of the round
    assert p.n_rows == 2 and sched.n_pending == 0


# ---------------------------------------------------------------------------
# state-cache persistence: round trip, corruption tolerance, evictions
# ---------------------------------------------------------------------------


def _fill(cache):
    keys = [("s", 8, False, 0, 2.0), ("s", 8, False, 1, math.inf),
            ("t", 16, True, 0, 4.0)]
    for i, k in enumerate(keys):
        L = k[1]
        cache.put(k, np.arange(L, dtype=np.float32) + i, float(i), 0.25 * i)
    return keys


def test_state_cache_save_load_round_trip(tmp_path):
    cache = StateCache()
    keys = _fill(cache)
    assert cache.dirty
    path = tmp_path / "cache.npz"
    assert cache.save(str(path)) == 3
    assert not cache.dirty

    fresh = StateCache()
    assert fresh.load(str(path)) == 3
    for k in keys:
        tau, off, comp = fresh.get(k)
        tau0, off0, comp0 = cache.get(k)
        assert np.array_equal(tau, tau0)        # mixed ring lengths, exact
        assert off == off0 and comp == comp0    # inf Δ keys survive JSON
    # live rows win over stale persisted rows on load
    newer = StateCache()
    newer.put(keys[0], np.full(8, 9.0, np.float32), 9.0, 9.0)
    assert newer.load(str(path)) == 2           # only the 2 missing rows
    assert newer.get(keys[0])[1] == np.float32(9.0)


def test_state_cache_load_trims_to_bound_in_lru_order(tmp_path):
    cache = StateCache()
    keys = _fill(cache)                          # saved order = LRU order
    path = tmp_path / "cache.npz"
    cache.save(str(path))
    small = StateCache(max_rows=2)
    assert small.load(str(path)) == 3
    assert len(small) == 2 and small.evictions == 1
    assert small.get(keys[0]) is None            # coldest row evicted
    assert small.get(keys[2]) is not None


def test_state_cache_load_tolerates_corruption(tmp_path, monkeypatch):
    cache = StateCache()
    _fill(cache)
    assert cache.load(str(tmp_path / "missing.npz")) == 0
    garbage = tmp_path / "garbage.npz"
    garbage.write_bytes(b"\x00not an npz archive")
    assert cache.load(str(garbage)) == 0
    assert len(cache) == 3                       # cache untouched either way

    good = tmp_path / "good.npz"
    cache.save(str(good))
    monkeypatch.setattr(state_cache_mod, "CACHE_FORMAT_VERSION",
                        CACHE_FORMAT_VERSION + 1)
    assert StateCache().load(str(good)) == 0     # version gate: cold start


def test_eviction_pressure_surfaces_in_service_stats():
    spec = WindowSweep(deltas=(2.0, 4.0), **COMMON)      # 8 burned rows
    svc = SweepService(state_cache_rows=4)
    svc.submit(spec, requester="alice")
    svc.drain()
    assert svc.state_cache.evictions == 4
    assert svc.stats.state_cache_evictions == 4
    assert svc.stats.state_cache_misses == svc.state_cache.misses == 8
    assert svc.stats.state_cache_hits == svc.state_cache.hits == 0


def test_persisted_cache_restart_is_bit_identical(tmp_path):
    """In-process restart gate: a second service loading the first's saved
    cache serves a follow-up entirely from persisted burn-in, bit-identical
    to a direct run (the daemon test below does the same across real
    processes)."""
    first = WindowSweep(deltas=(2.0, 4.0), **COMMON)
    longer = dataclasses.replace(first, n_steps=64)
    svc1 = SweepService()
    svc1.submit(first, requester="alice")
    svc1.drain()
    path = tmp_path / "cache.npz"
    assert svc1.state_cache.save(str(path)) == first.n_trajectories

    svc2 = SweepService()
    assert svc2.state_cache.load(str(path)) == first.n_trajectories
    svc2.submit(longer, requester="alice")
    (resp,) = svc2.drain()
    assert svc2.stats.rows_from_state_cache == first.n_trajectories
    assert svc2.stats.rows_burned == 0           # nothing re-burned
    assert resp.result.records == run_window_sweep(longer).records


# ---------------------------------------------------------------------------
# daemon: crash/restart resume, SIGTERM flush (real subprocesses)
# ---------------------------------------------------------------------------


def _drop_request(intake, name, spec, requester):
    tmp = os.path.join(intake, name + ".tmp")
    with open(tmp, "w") as fh:
        fh.write(json.dumps(encode_request(spec, requester)) + "\n")
    os.replace(tmp, os.path.join(intake, name))   # the intake drop protocol


def _daemon_args(intake, out, extra):
    return [sys.executable, "-m", "repro.service", "serve",
            "--intake", str(intake), "--out", str(out),
            "--poll", "0.05"] + extra


def test_daemon_crash_restart_resumes_from_persisted_cache(tmp_path):
    """The PR's acceptance gate: kill the daemon mid-queue (fault injection
    after pass 1 of 2), restart it on the persisted state cache, and the
    full response set is bit-identical to direct runs."""
    intake = tmp_path / "intake"
    intake.mkdir()
    out, cache = tmp_path / "responses.jsonl", tmp_path / "cache.npz"
    first = WindowSweep(deltas=(2.0, 4.0), **COMMON)
    longer = dataclasses.replace(first, n_steps=64)
    _drop_request(str(intake), "a.jsonl", first, "alice")
    _drop_request(str(intake), "b.jsonl", longer, "bob")
    args = _daemon_args(intake, out, [
        "--state-cache", str(cache), "--idle-exit-rounds", "2",
        "--max-files-per-round", "1"])   # meter intake: one file per round

    crash = subprocess.run(args + ["--crash-after-passes", "1"],
                           capture_output=True, text=True,
                           env=_subproc_env(), cwd=REPO)
    assert crash.returncode == 70, crash.stderr[-4000:]
    assert "fault injection" in crash.stderr
    # pass 1's response and the state cache hit disk before the crash
    assert len(out.read_text().strip().splitlines()) == 1
    assert cache.exists()
    assert (intake / "a.jsonl.done").exists()     # consumed pre-crash
    assert (intake / "b.jsonl").exists()          # survives for the restart

    restart = subprocess.run(args, capture_output=True, text=True,
                             env=_subproc_env(), cwd=REPO)
    assert restart.returncode == 0, restart.stderr[-4000:]
    assert f"restored {first.n_trajectories} burned row(s)" in restart.stderr
    assert f"{first.n_trajectories} rows from state cache" in restart.stderr

    by_requester = {}
    for line in out.read_text().strip().splitlines():
        resp = decode_response(json.loads(line))
        assert resp.error is None
        by_requester[resp.requester] = resp
    assert set(by_requester) == {"alice", "bob"}
    for who, spec in (("alice", first), ("bob", longer)):
        direct = run_window_sweep(spec)
        assert by_requester[who].result.records == direct.records, who


def test_daemon_sigterm_flushes_inflight_work(tmp_path):
    """SIGTERM while the scheduler is still *holding* the request (a huge
    ``max_wait_rounds``): the daemon force-drains, flushes the response,
    and exits 0 instead of dropping accepted work."""
    intake = tmp_path / "intake"
    intake.mkdir()
    out = tmp_path / "responses.jsonl"
    spec = WindowSweep(deltas=(2.0,), **COMMON)
    _drop_request(str(intake), "a.jsonl", spec, "alice")
    args = _daemon_args(intake, out, ["--max-wait-rounds", "1000000000"])
    proc = subprocess.Popen(args, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True,
                            env=_subproc_env(), cwd=REPO)
    try:
        deadline = time.time() + 300
        while not (intake / "a.jsonl.done").exists():   # accepted, held
            assert proc.poll() is None, proc.communicate()[1][-4000:]
            assert time.time() < deadline, "daemon never consumed intake"
            time.sleep(0.05)
        proc.send_signal(signal.SIGTERM)
        _, stderr = proc.communicate(timeout=300)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.communicate()
    assert proc.returncode == 0, stderr[-4000:]
    assert "flushing in-flight work" in stderr
    (line,) = out.read_text().strip().splitlines()
    resp = decode_response(json.loads(line))
    assert resp.requester == "alice" and resp.error is None
    assert resp.result.records == run_window_sweep(spec).records


def test_fake_devices_fails_loudly_when_jax_already_imported():
    script = ("import jax\n"
              "import sys\n"
              "from repro.service.__main__ import main\n"
              "sys.exit(main(['queue.jsonl', '--fake-devices', '2']))\n")
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True,
                         env=_subproc_env(), cwd=REPO)
    assert out.returncode == 2
    assert "--fake-devices" in out.stderr
    assert "already" in out.stderr and "silently" in out.stderr

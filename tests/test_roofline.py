"""HLO cost analysis: trip-count correctness and collective parsing."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze_hlo
from repro.launch.roofline import model_flops_for


def _compile_text(f, *avals):
    return jax.jit(f).lower(*avals).compile().as_text()


def test_xla_cost_analysis_counts_scan_once():
    """Documents WHY hlo_cost exists: XLA's own analysis undercounts loops."""
    d = 256

    def body(x, w):
        return jnp.tanh(x @ w), None

    def scanned(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((32, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, d, d), jnp.float32)
    compiled = jax.jit(scanned).lower(x, ws).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    theory = 8 * 2 * 32 * d * d
    assert ca["flops"] < theory / 4           # XLA: body counted once
    c = analyze_hlo(compiled.as_text())
    np.testing.assert_allclose(c.flops, theory, rtol=0.01)


def test_unrolled_matches_theory():
    d = 256

    def unrolled(x, ws):
        for i in range(4):
            x = jnp.tanh(x @ ws[i])
        return x

    txt = _compile_text(unrolled, jax.ShapeDtypeStruct((32, d), jnp.float32),
                        jax.ShapeDtypeStruct((4, d, d), jnp.float32))
    c = analyze_hlo(txt)
    np.testing.assert_allclose(c.flops, 4 * 2 * 32 * d * d, rtol=0.01)


def test_grad_through_scan():
    d = 128

    def body(x, w):
        return jnp.tanh(x @ w), None

    def loss(ws, x):
        y, _ = jax.lax.scan(body, x, ws)
        return (y ** 2).sum()

    txt = _compile_text(jax.grad(loss),
                        jax.ShapeDtypeStruct((8, d, d), jnp.float32),
                        jax.ShapeDtypeStruct((16, d), jnp.float32))
    c = analyze_hlo(txt)
    np.testing.assert_allclose(c.flops, 3 * 8 * 2 * 16 * d * d, rtol=0.02)


def test_einsum_batch_dims():
    def attn(q, k):
        return jnp.einsum("bqhd,bkhd->bhqk", q, k)

    q = jax.ShapeDtypeStruct((2, 64, 4, 32), jnp.bfloat16)
    txt = _compile_text(attn, q, q)
    c = analyze_hlo(txt)
    np.testing.assert_allclose(c.flops, 2 * 2 * 4 * 64 * 64 * 32, rtol=0.01)


def test_dynamic_slice_bytes_not_full_operand():
    def f(k):
        def body(acc, i):
            blk = jax.lax.dynamic_slice_in_dim(k, i * 64, 64, axis=0)
            return acc + blk.sum(), None
        out, _ = jax.lax.scan(body, 0.0, jnp.arange(16))
        return out

    txt = _compile_text(f, jax.ShapeDtypeStruct((1024, 128), jnp.float32))
    c = analyze_hlo(txt)
    full = 1024 * 128 * 4
    assert c.bytes < 4 * full, (c.bytes, full)   # not 16x the array


def test_collective_parse():
    hlo = """
ENTRY %main (p: f32[16,64]) -> f32[16,64] {
  %p = f32[16,64]{1,0} parameter(0)
  %ar = f32[16,64]{1,0} all-reduce(%p), replica_groups={}, to_apply=%add
  %ag = f32[64,64]{1,0} all-gather(%ar), dimensions={0}
  ROOT %cp = f32[16,64]{1,0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    c = analyze_hlo(hlo, entry="main")
    assert c.coll["all-reduce"] == 16 * 64 * 4
    assert c.coll["all-gather"] == 64 * 64 * 4
    assert c.coll["collective-permute"] == 16 * 64 * 4
    assert c.coll_msgs == 3


def test_split_args_nested_tuple_result():
    """Tuple-typed results must not be mistaken for the operand list.

    ``%t = (f32[2], (f32[4], s32[])) tuple(%a, %b)`` — the first ``(`` of
    the RHS belongs to the (arbitrarily nested) result type; splitting from
    there would yield type fragments instead of operands and shift every
    downstream operand↔parameter alignment.
    """
    from repro.launch.hlo_cost import _split_args, parse_computations
    hlo = """
ENTRY %main (a: f32[2], b: f32[4]) -> (f32[2], (f32[4], s32[])) {
  %a = f32[2]{0} parameter(0)
  %b = f32[4]{0} parameter(1)
  %s = s32[] constant(3)
  %inner = (f32[4], s32[]) tuple(%b, %s)
  ROOT %t = (f32[2], (f32[4], s32[])) tuple(%a, %inner)
}
"""
    comp = parse_computations(hlo)["main"]
    ops = {o.name: o for o in comp.ops}
    assert ops["t"].opcode == "tuple"
    assert ops["inner"].opcode == "tuple"
    _texts, names = _split_args(ops["t"])
    assert names == ["a", "inner"]
    _texts, names = _split_args(ops["inner"])
    assert names == ["b", "s"]
    # nested-tuple analysis must also not crash the cost walk
    analyze_hlo(hlo, entry="main")


def test_split_args_nested_tuple_operands():
    """Inline tuple-typed operands (commas at bracket depth) don't split."""
    from repro.launch.hlo_cost import _split_args, parse_computations
    hlo = """
ENTRY %main (p: (f32[8,4], s32[2])) -> f32[8,4] {
  %p = (f32[8,4]{1,0}, s32[2]{0}) parameter(0)
  ROOT %g = f32[8,4]{1,0} get-tuple-element((f32[8,4], s32[2]) %p), index=0
}
"""
    comp = parse_computations(hlo)["main"]
    g = [o for o in comp.ops if o.name == "g"][0]
    assert g.opcode == "get-tuple-element"
    texts, names = _split_args(g)
    assert names == ["p"] and len(texts) == 1


def test_collective_permute_source_target_pairs():
    from repro.launch.hlo_cost import collective_permutes
    hlo = """
ENTRY %main (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  %cp0 = f32[16]{0} collective-permute(%p), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
  %st = (f32[16], f32[16]) collective-permute-start(%cp0), source_target_pairs={{3,2},{2,1},{1,0},{0,3}}
  ROOT %dn = f32[16]{0} collective-permute-done(%st)
}
"""
    pairs = collective_permutes(hlo)
    assert pairs == [
        [(0, 1), (1, 2), (2, 3), (3, 0)],
        [(3, 2), (2, 1), (1, 0), (0, 3)],
    ]
    # ...and on a real lowered ring program: every hop is +-1 on the ring
    assert collective_permutes("ENTRY %e (x: f32[2]) -> f32[2] {}") == []


def test_model_flops_for():
    from repro.configs import get_config, get_shape
    cfg = get_config("llama3.2-1b")
    mf = model_flops_for(cfg, get_shape("train_4k"))
    np.testing.assert_allclose(mf, 6 * cfg.n_params() * 4096 * 256)
    mf_d = model_flops_for(cfg, get_shape("decode_32k"))
    np.testing.assert_allclose(mf_d, 2 * cfg.n_params() * 128)

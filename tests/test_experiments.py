"""Window-sweep subsystem: batched-vs-serial parity, Δ=inf limit, bounds.

The batched sweep's contract is *bit-identity* with a serial per-Δ engine
loop: ``PDESEngine.init_sweep`` lays the Δ grid on the ensemble axis and
assigns window ``w`` the counter-stream rows ``trial_base = w * replicas``,
so the serial oracle running those rows produces the exact same float32
trajectories — asserted with array_equal, never allclose.
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core import PDESConfig, measurement
from repro.core.engine import PDESEngine
from repro.experiments import (WindowSweep, efficiency, find_optimal_window,
                               optimal_windows, run_window_sweep,
                               serial_window_sweep)

SINGLE = ("reference", "pallas", "pallas_multistep")


@pytest.mark.parametrize("backend", SINGLE)
def test_batched_sweep_bit_identical_to_serial_loop(backend):
    """One batched pass == per-Δ loop: same tau, offset, and records."""
    cfg = PDESConfig(L=64, n_v=2)
    deltas = (0.5, 4.0, math.inf)
    R = 4
    eng = PDESEngine(cfg, backend=backend, k_fuse=8)
    st, drows = eng.init_sweep(deltas, R)
    st = eng.burn_in(st, 3, 24, deltas=drows)
    st, _ = eng.run(st, 3, 16, deltas=drows)
    for w, d in enumerate(deltas):
        cfg_w = dataclasses.replace(cfg, delta=float(d))
        eng_w = PDESEngine(cfg_w, backend=backend, k_fuse=8)
        s2 = eng_w.burn_in(eng_w.init(R), 3, 24, trial_base=w * R)
        s2, _ = eng_w.run(s2, 3, 16, trial_base=w * R)
        rows = slice(w * R, (w + 1) * R)
        np.testing.assert_array_equal(
            np.asarray(st.tau)[rows], np.asarray(s2.tau),
            err_msg=f"{backend} delta={d}")
        np.testing.assert_array_equal(
            np.asarray(st.offset)[rows], np.asarray(s2.offset),
            err_msg=f"{backend} delta={d}")


def test_run_window_sweep_matches_serial_records():
    """The experiment layer reduces both paths to identical records."""
    spec = WindowSweep(Ls=(32, 48), n_vs=(1, 3), deltas=(1.0, 8.0, math.inf),
                       replicas=4, n_steps=48, burn_in=32,
                       backend="pallas_multistep", k_fuse=8, seed=5)
    batched = run_window_sweep(spec)
    serial = serial_window_sweep(spec)
    assert batched.records == serial.records
    assert len(batched.records) == 2 * 2 * 3
    # grid bookkeeping: every (L, n_v, Δ) combination appears exactly once
    keys = {(r.L, r.n_v, r.delta) for r in batched.records}
    assert len(keys) == len(batched.records)


def test_delta_inf_rows_reproduce_unconstrained_case():
    """inf rows of a sweep == a plain engine run with no window at all."""
    cfg = PDESConfig(L=48, n_v=1)          # delta defaults to inf
    R = 4
    eng = PDESEngine(cfg, backend="reference", k_fuse=8)
    st, drows = eng.init_sweep((2.0, math.inf), R)
    st, _ = eng.run(st, 9, 32, deltas=drows)
    plain = PDESEngine(cfg, backend="reference", k_fuse=8)
    s2, _ = plain.run(plain.init(R), 9, 32, trial_base=R)
    np.testing.assert_array_equal(np.asarray(st.tau)[R:], np.asarray(s2.tau))
    np.testing.assert_array_equal(np.asarray(st.offset)[R:],
                                  np.asarray(s2.offset))


def test_width_bounded_by_window_for_small_delta():
    """Hard bound: horizon extent <= Δ + max increment, per step and row."""
    cfg = PDESConfig(L=64, n_v=1)
    deltas = (0.5, 2.0, 8.0)
    R = 4
    eng = PDESEngine(cfg, backend="pallas_multistep", k_fuse=8)
    st, drows = eng.init_sweep(deltas, R)
    st = eng.burn_in(st, 1, 128, deltas=drows)
    _, stats = eng.run(st, 1, 64, deltas=drows)
    eta_max = 25 * math.log(2)             # decode_words: -log(2^-25)
    spread = np.asarray(stats.max_dev) + np.asarray(stats.min_dev)  # (T, B)
    per_window = spread.reshape(spread.shape[0], len(deltas), R)
    for w, d in enumerate(deltas):
        assert per_window[:, w].max() <= d + eta_max
    # and the bound is doing real work: the tightest window's horizon is
    # strictly narrower than the loosest one's
    assert per_window[:, 0].mean() < per_window[:, -1].mean()


def test_sweep_reduce_shapes_and_errors():
    spec = WindowSweep(Ls=(32,), deltas=(1.0, math.inf), replicas=3,
                       n_steps=32, burn_in=16, seed=2)
    res = run_window_sweep(spec)
    assert all(np.isfinite([r.u, r.w2, r.rate, r.spread]).all()
               for r in res.records)
    with pytest.raises(ValueError):
        measurement.steady_start(10, steady_frac=0.0)
    with pytest.raises(ValueError):
        WindowSweep(deltas=())
    with pytest.raises(ValueError):
        WindowSweep(deltas=(1.0, 1.0))
    eng = PDESEngine(PDESConfig(L=16), backend="reference")
    with pytest.raises(ValueError):        # wrong deltas length
        eng.run(eng.init(4), 0, 4, deltas=np.ones(3))


def test_optimal_window_interior_on_synthetic_curve():
    """Δ* maximizes u/(1+w); rising u + rising w => interior optimum."""
    spec = WindowSweep(Ls=(32,), deltas=(0.5, 2.0, 8.0), replicas=2,
                       n_steps=16, burn_in=8, seed=4)
    res = run_window_sweep(spec)
    # synthetic override of the physics: u saturating, w growing
    synth = [(0.3, 0.0), (0.8, 1.0), (0.9, 3.0)]
    recs = tuple(dataclasses.replace(r, u=u, w=w)
                 for (u, w), r in zip(synth, sorted(res.records,
                                                    key=lambda r: r.delta)))
    ow = find_optimal_window(dataclasses.replace(res, records=recs),
                             L=32, n_v=1)
    # curve: 0.3/1, 0.8/2, 0.9/4 -> argmax at the middle grid point
    assert ow.delta_star == 2.0 and ow.interior
    np.testing.assert_allclose(
        efficiency([r.u for r in recs], [r.w for r in recs]), ow.eff)
    # and on the real (tiny) sweep the helper runs end to end
    assert len(optimal_windows(res)) == 1


def test_ensemble_steady_state_sweep_matches_plain_steady_state():
    """ensemble's sweep wrapper: row block 0 runs the same trajectories as a
    plain engine steady_state call (trial_base 0), so the time/ensemble
    means agree to reduction-order tolerance."""
    from repro.core import ensemble
    cfg = PDESConfig(L=32, n_v=1)
    deltas = (2.0, math.inf)
    out = ensemble.steady_state_sweep(
        cfg, deltas, n_trials=4, seed=3, burn_in_steps=32, measure_steps=32,
        backend="reference", engine_opts={"k_fuse": 8})
    assert [ss.cfg.delta for ss in out] == [2.0, math.inf]
    plain = ensemble.steady_state(
        dataclasses.replace(cfg, delta=2.0), n_trials=4, seed=3,
        burn_in_steps=32, measure_steps=32, backend="reference",
        engine_opts={"k_fuse": 8})
    np.testing.assert_allclose(out[0].utilization, plain.utilization,
                               rtol=1e-5)
    np.testing.assert_allclose(out[0].w2, plain.w2, rtol=1e-4)
    # windowed row block is the constrained one
    assert out[0].utilization <= out[1].utilization + 0.05


def test_sweep_result_json_roundtrip(tmp_path):
    spec = WindowSweep(Ls=(16,), deltas=(1.0, math.inf), replicas=2,
                       n_steps=16, burn_in=8, seed=6)
    res = run_window_sweep(spec)
    p = res.to_json(tmp_path / "sweep.json")
    import json
    data = json.loads(p.read_text())
    assert data["spec"]["deltas"] == [1.0, "inf"]
    assert len(data["records"]) == 2
    assert data["records"][1]["delta"] == "inf"
    assert all(math.isfinite(r["u"]) for r in data["records"])

"""Property-based tests (hypothesis) on system invariants.

``hypothesis`` is an optional test dependency (pyproject ``[test]`` extra);
on a bare interpreter this module must *skip*, never error at collection.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed (optional "
                    "test dependency; pip install -e '.[test]')")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import PDESConfig, horizon
from repro.core.events import counter_bits_block
from repro.data.pipeline import DataConfig, make_batch

SET = dict(max_examples=20, deadline=None)


class TestEventStream:
    @given(seed=st.integers(0, 2**31 - 1), step=st.integers(0, 10_000),
           n_v=st.integers(1, 1000))
    @settings(**SET)
    def test_decode_events_ranges(self, seed, step, n_v):
        cfg = PDESConfig(L=32, n_v=n_v)
        bits = horizon.event_bits(jax.random.key(seed), jnp.int32(step),
                                  (2, 32))
        is_l, is_r, eta = horizon.decode_events(bits, cfg)
        assert (np.asarray(eta) > 0).all()          # Exp(1) strictly positive
        if n_v == 1:
            assert np.asarray(is_l).all() and np.asarray(is_r).all()

    @given(seed=st.integers(0, 2**31 - 1), step=st.integers(0, 100_000))
    @settings(**SET)
    def test_counter_bits_deterministic_and_slice_consistent(self, seed, step):
        """Any sub-block equals the corresponding slice of the full block —
        the property that makes halo regeneration correct (DESIGN.md B4)."""
        full = counter_bits_block(seed, jnp.int32(step), jnp.int32(0),
                                  jnp.int32(0), 8, 32)
        sub = counter_bits_block(seed, jnp.int32(step), jnp.int32(2),
                                 jnp.int32(5), 3, 7)
        np.testing.assert_array_equal(np.asarray(full[2:5, 5:12]),
                                      np.asarray(sub))

    def test_counter_bits_statistics(self):
        """Counter stream is statistically uniform enough for the physics."""
        bits = counter_bits_block(7, jnp.int32(3), jnp.int32(0), jnp.int32(0),
                                  256, 256)
        u = np.asarray(bits[..., 1], dtype=np.float64) / 2**32
        assert abs(u.mean() - 0.5) < 5e-3
        assert abs(u.std() - math.sqrt(1 / 12)) < 5e-3
        # exponential moments from word 1 via the production decode
        cfg = PDESConfig(L=256, n_v=1)
        _, _, eta = horizon.decode_events(jnp.asarray(bits), cfg)
        e = np.asarray(eta, dtype=np.float64)
        assert abs(e.mean() - 1.0) < 2e-2            # Exp(1): mean 1
        assert abs(e.std() - 1.0) < 3e-2             # Exp(1): std 1


class TestPDESInvariants:
    @given(delta=st.sampled_from([0.5, 2.0, 10.0, math.inf]),
           n_v=st.sampled_from([1, 3, 10]),
           seed=st.integers(0, 1000))
    @settings(max_examples=8, deadline=None)
    def test_window_and_monotonicity(self, delta, n_v, seed):
        cfg = PDESConfig(L=32, n_v=n_v, delta=delta)
        state = horizon.init_state(cfg, 2)
        key = jax.random.key(seed)
        prev_gvt = np.full(2, -1e30)
        for _ in range(5):
            tau_before = np.asarray(state.tau) + np.asarray(state.offset)[:, None]
            state, stats = horizon.run(state, key, cfg, 8)
            tau_after = np.asarray(state.tau) + np.asarray(state.offset)[:, None]
            # monotone local clocks
            assert (tau_after >= tau_before - 1e-3).all()
            # GVT never decreases (per trial)
            gvt = np.asarray(stats.gvt)               # (T, B)
            assert (gvt.min(axis=0) >= prev_gvt - 1e-3).all()
            prev_gvt = gvt.max(axis=0)
            if math.isfinite(delta):
                spread = tau_after.max(1) - tau_after.min(1)
                assert (spread <= delta + 16.0).all()

    @given(seed=st.integers(0, 100))
    @settings(max_examples=5, deadline=None)
    def test_utilization_bounds(self, seed):
        cfg = PDESConfig(L=16, n_v=2, delta=4.0)
        _, stats = horizon.run(horizon.init_state(cfg, 4),
                               jax.random.key(seed), cfg, 32)
        u = np.asarray(stats.utilization)
        assert (u >= 1.0 / 16 - 1e-6).all()          # at least the min PE
        assert (u <= 1.0).all()


class TestDataPipeline:
    @given(step=st.integers(0, 10_000))
    @settings(**SET)
    def test_batches_deterministic(self, step):
        dc = DataConfig(vocab_size=128, seq_len=16, global_batch=2)
        a = make_batch(dc, step)
        b = make_batch(dc, step)
        np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                      np.asarray(b["tokens"]))

    @given(step=st.integers(0, 1000), vocab=st.sampled_from([64, 1000, 50000]))
    @settings(**SET)
    def test_tokens_in_vocab(self, step, vocab):
        dc = DataConfig(vocab_size=vocab, seq_len=32, global_batch=2)
        b = make_batch(dc, step)
        t = np.asarray(b["tokens"])
        assert t.min() >= 0 and t.max() < vocab
        # labels are next tokens
        np.testing.assert_array_equal(np.asarray(b["labels"])[:, :-1],
                                      t[:, 1:])

    def test_zipf_skew(self):
        dc = DataConfig(vocab_size=1000, seq_len=512, global_batch=8)
        t = np.asarray(make_batch(dc, 0)["tokens"])
        # low ranks must be much more frequent than the tail
        head = (t < 10).mean()
        assert head > 0.05, head

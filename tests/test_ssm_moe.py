"""Mamba2 SSD and MoE dispatch correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as M
from repro.models import ssm as S

KEY = jax.random.key(0)


class TestSSM:
    @pytest.mark.parametrize("chunk", [4, 8, 16])
    def test_chunked_equals_sequential(self, chunk):
        spec = S.SSMSpec(d_model=64, d_state=16, d_conv=4, expand=2,
                         head_dim=16, chunk=chunk)
        params = S.ssm_init(KEY, spec, jnp.float32)
        B, T = 2, 32
        x = jax.random.normal(jax.random.fold_in(KEY, 1), (B, T, 64)) * 0.5
        y_chunked, cache_after = S.ssm_apply(x, params, spec, jnp.float32)
        cache = S.ssm_init_cache(B, spec)
        ys = []
        for t in range(T):
            y_t, cache = S.ssm_decode_step(x[:, t], cache, params, spec,
                                           jnp.float32)
            ys.append(y_t)
        y_seq = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_seq),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(cache_after["ssm"]),
                                   np.asarray(cache["ssm"]), rtol=2e-4,
                                   atol=2e-4)
        np.testing.assert_allclose(np.asarray(cache_after["conv"]),
                                   np.asarray(cache["conv"]), rtol=1e-5,
                                   atol=1e-5)

    def test_state_decay(self):
        """With zero input the SSM state decays monotonically (A < 0)."""
        spec = S.SSMSpec(d_model=32, d_state=8, head_dim=8, chunk=8)
        params = S.ssm_init(KEY, spec, jnp.float32)
        cache = S.ssm_init_cache(1, spec)
        cache["ssm"] = cache["ssm"] + 1.0
        x0 = jnp.zeros((1, 32))
        _, c1 = S.ssm_decode_step(x0, cache, params, spec, jnp.float32)
        assert (np.abs(np.asarray(c1["ssm"])) <=
                np.abs(np.asarray(cache["ssm"])) + 1e-6).all()


class TestMoE:
    def test_matches_dense_reference(self):
        spec = M.MoESpec(n_experts=4, top_k=2, capacity_factor=8.0)
        d, f = 32, 64
        params = M.moe_init(KEY, d, f, spec, jnp.float32)
        B, Ss = 2, 16
        x = jax.random.normal(jax.random.fold_in(KEY, 1), (B, Ss, d))
        out, aux = M.moe_apply(x, params, spec, compute_dtype=jnp.float32)
        assert aux["drop_frac"] == 0.0
        logits = x @ params["router"]
        tv, ti = jax.lax.top_k(logits, 2)
        gate = jax.nn.softmax(tv, axis=-1)
        ref = np.zeros((B, Ss, d), np.float32)
        for b in range(B):
            for s in range(Ss):
                for kk in range(2):
                    e = int(ti[b, s, kk])
                    h = x[b, s] @ params["wi"][e]
                    h = jax.nn.silu(h) * (x[b, s] @ params["wg"][e])
                    ref[b, s] += float(gate[b, s, kk]) * np.asarray(
                        h @ params["wo"][e])
        np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)

    def test_capacity_drops(self):
        spec = M.MoESpec(n_experts=4, top_k=2, capacity_factor=0.25)
        params = M.moe_init(KEY, 32, 64, spec, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(KEY, 2), (2, 16, 32))
        out, aux = M.moe_apply(x, params, spec, compute_dtype=jnp.float32)
        assert 0.4 < float(aux["drop_frac"]) < 0.95
        assert np.isfinite(np.asarray(out)).all()

    def test_balanced_router_lb_loss(self):
        """Perfectly uniform routing gives lb_loss ~ 1 (switch normalization)."""
        spec = M.MoESpec(n_experts=8, top_k=1, capacity_factor=4.0)
        params = M.moe_init(KEY, 16, 32, spec, jnp.float32)
        params["router"] = jnp.zeros_like(params["router"])  # uniform logits
        x = jax.random.normal(jax.random.fold_in(KEY, 3), (4, 64, 16))
        _, aux = M.moe_apply(x, params, spec, compute_dtype=jnp.float32)
        assert 0.9 < float(aux["lb_loss"]) < 1.3

    def test_capacity_helper(self):
        assert M.capacity(4096, M.MoESpec(8, 2, 1.25)) == 1280
        assert M.capacity(1, M.MoESpec(128, 2, 1.0)) >= 1

    def test_differentiable(self):
        spec = M.MoESpec(n_experts=4, top_k=2, capacity_factor=2.0)
        params = M.moe_init(KEY, 16, 32, spec, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 8, 16))

        def loss(p):
            out, aux = M.moe_apply(x, p, spec, compute_dtype=jnp.float32)
            return (out ** 2).sum() + aux["lb_loss"]

        g = jax.grad(loss)(params)
        gn = sum(float(jnp.sum(jnp.abs(v))) for v in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0

"""PDESEngine: cross-backend parity and driver semantics.

The engine's contract is that every backend consumes the same counter-based
event stream and rebases on the same per-chunk schedule, so trajectories are
*bit-identical* — asserted with array_equal, not allclose.  (The ``sharded``
backend is covered separately in tests/test_distributed_pdes.py since it
needs a multi-device subprocess.)
"""
import math

import numpy as np
import pytest

from repro.core import PDESConfig
from repro.core.engine import BACKENDS, EngineConfig, PDESEngine

SINGLE = ("reference", "pallas", "pallas_multistep")


@pytest.mark.parametrize("delta", [math.inf, 10.0])
@pytest.mark.parametrize("rd_mode", [False, True])
def test_cross_backend_parity(delta, rd_mode):
    """reference == pallas == pallas_multistep: bit-identical tau + offset,
    matching StepStats, from the shared event_bits stream."""
    cfg = PDESConfig(L=128, n_v=4, delta=delta, rd_mode=rd_mode)
    outs = {}
    for backend in SINGLE:
        eng = PDESEngine(cfg, backend=backend, k_fuse=16)
        state = eng.init(8)
        state, stats = eng.run(state, seed=5, n_steps=40)
        outs[backend] = (state, stats)
    ref_state, ref_stats = outs["reference"]
    assert int(ref_state.step) == 40
    for backend in SINGLE[1:]:
        state, stats = outs[backend]
        np.testing.assert_array_equal(np.asarray(state.tau),
                                      np.asarray(ref_state.tau), err_msg=backend)
        np.testing.assert_array_equal(np.asarray(state.offset),
                                      np.asarray(ref_state.offset),
                                      err_msg=backend)
        for field in stats._fields:
            np.testing.assert_allclose(
                np.asarray(getattr(stats, field)),
                np.asarray(getattr(ref_stats, field)),
                rtol=1e-6, atol=1e-6, err_msg=f"{backend}.{field}")


@pytest.mark.parametrize("backend", SINGLE)
def test_remainder_chunks_and_resume(backend):
    """n_steps not divisible by k_fuse, and run-in-two-pieces == run-once."""
    cfg = PDESConfig(L=64, n_v=2, delta=8.0)
    eng = PDESEngine(cfg, backend=backend, k_fuse=8)
    a = eng.init(4)
    a, _ = eng.run(a, 3, 11)
    a, _ = eng.run(a, 3, 8)
    b = eng.init(4)
    b, _ = eng.run(b, 3, 19)
    # same stream position; chunk boundaries differ -> rebase schedule
    # differs, so compare absolute times with fp tolerance.
    ta = np.asarray(a.tau) + np.asarray(a.offset)[:, None]
    tb = np.asarray(b.tau) + np.asarray(b.offset)[:, None]
    np.testing.assert_allclose(ta, tb, rtol=1e-6, atol=1e-5)
    assert int(a.step) == int(b.step) == 19


def test_run_mean_matches_run():
    cfg = PDESConfig(L=64, n_v=3, delta=5.0)
    eng = PDESEngine(cfg, backend="pallas_multistep", k_fuse=8)
    st0 = eng.init(4)
    _, per_step = eng.run(st0, 9, 24)
    st_m, mean = eng.run_mean(st0, 9, 24)
    for field in mean._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(mean, field)),
            np.asarray(getattr(per_step, field)).mean(axis=0),
            rtol=1e-5, atol=1e-5, err_msg=field)
    assert int(st_m.step) == 24


def test_burn_in_advances_state():
    cfg = PDESConfig(L=32, n_v=1, delta=4.0)
    eng = PDESEngine(cfg, backend="reference")
    st = eng.burn_in(eng.init(4), 0, 50)
    assert int(st.step) == 50
    assert float(np.asarray(st.offset).min()) > 0  # GVT advanced


def test_stale_window_is_conservative():
    """Stale window ⊆ exact window: utilization can only drop, and the
    engine's stale mode equals the distributed stale-reference oracle."""
    from repro.core import distributed as D
    cfg = PDESConfig(L=64, n_v=1, delta=4.0)
    u = {}
    for window in ("exact", "stale"):
        eng = PDESEngine(cfg, backend="pallas" if window == "stale"
                         else "reference", window=window, k_fuse=8)
        st = eng.init(16)
        st = eng.burn_in(st, 1, 96)
        _, mean = eng.run_mean(st, 1, 200)
        u[window] = float(np.asarray(mean.utilization).mean())
    assert u["stale"] <= u["exact"] + 0.01
    # engine stale == run_reference(stale_every=K) on the same stream
    eng = PDESEngine(cfg, backend="reference", window="stale", k_fuse=8)
    st, _ = eng.run(eng.init(6), 7, 24)
    tau_ref, _ = D.run_reference(cfg, n_trials=6, n_steps=24, seed=7,
                                 stale_every=8)
    ours = np.asarray(st.tau) + np.asarray(st.offset)[:, None]
    np.testing.assert_allclose(ours, np.asarray(tau_ref), rtol=1e-6,
                               atol=1e-5)


def test_engine_validation():
    cfg = PDESConfig(L=16, n_v=1)
    with pytest.raises(ValueError):
        PDESEngine(cfg, backend="nope")
    with pytest.raises(ValueError):
        PDESEngine(cfg, backend="pallas_multistep", window="stale")
    with pytest.raises(ValueError):
        PDESEngine(cfg, backend="sharded")          # no mesh
    with pytest.raises(ValueError):
        EngineConfig(window="sorta")
    eng = PDESEngine(cfg)
    with pytest.raises(ValueError):
        eng.run(eng.init(2), 0, 0)
    assert set(BACKENDS) >= set(SINGLE)


def test_engine_matches_horizon_semantics():
    """The engine's reference backend is horizon._one_step on the counter
    stream: per-step utilization starts at 1 (synchronized start) and the
    Δ=0 limit serializes, exactly like the horizon tests."""
    cfg = PDESConfig(L=16, n_v=1, delta=0.0)
    eng = PDESEngine(cfg, backend="pallas_multistep", k_fuse=8)
    st = eng.burn_in(eng.init(16), 2, 48)
    _, mean = eng.run_mean(st, 2, 400)
    u = float(np.asarray(mean.utilization).mean())
    assert abs(u - 1.0 / 16) < 0.02, u
    cfg2 = PDESConfig(L=32, n_v=1)
    eng2 = PDESEngine(cfg2, backend="pallas")
    _, stats = eng2.run(eng2.init(4), 0, 1)
    np.testing.assert_allclose(np.asarray(stats.utilization), 1.0)

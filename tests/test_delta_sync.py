"""Δ-window bounded-asynchrony scheduler: paper-fit agreement + invariants."""
import numpy as np

from repro.distributed.delta_sync import (DeltaScheduler, DeltaSyncConfig,
                                          gated_microbatch_weights,
                                          predicted_utilization)


def test_utilization_matches_paper_rd_fit():
    """The DP scheduler *is* the paper's Δ-constrained RD model.

    Finite-L utilization lies above the infinite-L fit (paper Fig. 5: RD
    curves fall with L), so we check (a) the monotone L-trend and (b) the
    1/L-extrapolated value against fit (A.1) — the capacity-planning claim.
    The high-resolution version of this comparison is benchmarks fig6.
    """
    from repro.core.scaling import rational_extrapolate
    delta = 10.0
    us, Ls = [], [64, 128, 256, 512]
    for L in Ls:
        sch = DeltaScheduler(DeltaSyncConfig(n_workers=L, delta=delta, seed=3))
        for _ in range(400):          # burn-in past the Δ-saturation
            sch.offer()
        sch.committed = sch.attempted = 0
        for _ in range(800):
            sch.offer()
        us.append(sch.utilization)
    assert all(a > b for a, b in zip(us, us[1:])), us   # falls with L
    ex = rational_extrapolate(Ls, us)
    pred = predicted_utilization(delta)
    # coarse bound: 4 noisy points over a small L range; the precise version
    # (L -> 4096, u_inf within ~0.04 of A.1) is benchmarks fig6_rd_limit.
    assert abs(ex.u_inf - pred) < 0.1, (ex.u_inf, pred)


def test_bounded_staleness_invariant():
    """No worker ever exceeds GVT + Δ by more than its last step length."""
    rng = np.random.default_rng(0)
    sch = DeltaScheduler(DeltaSyncConfig(n_workers=64, delta=5.0))
    for _ in range(400):
        durations = rng.exponential(1.0, 64)
        before = sch.tau.copy()
        gvt_before = before.min()
        allowed = sch.offer(durations)
        # a worker beyond the window must have been blocked
        assert not (allowed & (before > 5.0 + gvt_before)).any()
    assert sch.spread <= 5.0 + 15.0    # Δ + exp tail


def test_gvt_monotone_nondecreasing():
    sch = DeltaScheduler(DeltaSyncConfig(n_workers=32, delta=3.0))
    g = sch.gvt
    for _ in range(200):
        sch.offer()
        assert sch.gvt >= g - 1e-12
        g = sch.gvt


def test_delta_zero_serializes():
    sch = DeltaScheduler(DeltaSyncConfig(n_workers=16, delta=0.0))
    sch.offer()                        # first round: all tied at 0 -> all go
    for _ in range(100):
        allowed = sch.offer()
        assert allowed.sum() <= 2      # generically exactly the argmin
    assert sch.utilization < 0.3


def test_delta_inf_never_blocks():
    sch = DeltaScheduler(DeltaSyncConfig(n_workers=16, delta=np.inf))
    for _ in range(50):
        assert sch.offer().all()


def test_gated_weights_unbiased():
    sch = DeltaScheduler(DeltaSyncConfig(n_workers=8, delta=4.0))
    for _ in range(100):
        w, mask = gated_microbatch_weights(sch)
        if mask.any():
            np.testing.assert_allclose(w.sum(), 8.0)   # mean stays a mean
        assert (w[~mask] == 0).all()


def test_checkpoint_frontier():
    sch = DeltaScheduler(DeltaSyncConfig(n_workers=8, delta=2.0))
    last = 0.0
    fired = 0
    for _ in range(300):
        sch.offer()
        if sch.checkpoint_due(last, interval=5.0):
            # everything <= gvt is committed on every worker
            assert (sch.tau >= sch.gvt - 1e-12).all()
            last = sch.gvt
            fired += 1
    assert fired >= 3

"""Pallas kernel validation: shape/param sweeps vs the pure-jnp oracle."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import horizon
from repro.core.horizon import PDESConfig
from repro.kernels import ops, ref

KEY = jax.random.key(7)

SWEEP = [
    # (L, n_v, delta, rd_mode, B)
    (8, 1, math.inf, False, 3),
    (64, 1, math.inf, False, 12),
    (32, 10, 5.0, False, 8),
    (128, 3, 1.0, False, 4),
    (256, 1, 0.0, False, 2),
    (64, 100, 10.0, True, 8),
    (512, 7, 100.0, False, 1),
]


def _state_and_bits(cfg, B, steps=7):
    state = horizon.init_state(cfg, B)
    state = horizon.burn_in(state, KEY, cfg, steps)
    bits = horizon.event_bits(KEY, state.step, state.tau.shape)
    return state, bits


@pytest.mark.parametrize("L,n_v,delta,rd,B", SWEEP)
def test_pdes_step_matches_ref(L, n_v, delta, rd, B):
    cfg = PDESConfig(L=L, n_v=n_v, delta=delta, rd_mode=rd)
    state, bits = _state_and_bits(cfg, B)
    tau_h = ops.ring_halo(state.tau)
    gvt = jnp.min(state.tau, axis=-1, keepdims=True)
    t1, s1 = ops.pdes_step(tau_h, bits, gvt, n_v=n_v, delta=delta, rd_mode=rd)
    t2, _, s2 = ref.pdes_step_ref(tau_h, bits, gvt, n_v=n_v, delta=delta,
                                  rd_mode=rd)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    for k in s1:
        np.testing.assert_allclose(np.asarray(s1[k]), np.asarray(s2[k]),
                                   rtol=1e-6)


@pytest.mark.parametrize("L,n_v,delta,rd,B", SWEEP)
def test_pdes_step_matches_core(L, n_v, delta, rd, B):
    """Kernel path == horizon.step_core (the system's own semantics)."""
    cfg = PDESConfig(L=L, n_v=n_v, delta=delta, rd_mode=rd)
    state, bits = _state_and_bits(cfg, B)
    t1, _ = ops.step_ring(state.tau, bits, cfg)
    is_l, is_r, eta = horizon.decode_events(bits, cfg)
    t2, _, _ = horizon.step_core(state.tau, is_l, is_r, eta, cfg)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))


@pytest.mark.parametrize("L,n_v,delta,rd,B", SWEEP[:5])
@pytest.mark.parametrize("K", [1, 4, 6])
def test_pdes_multistep_matches_ref(L, n_v, delta, rd, B, K):
    cfg = PDESConfig(L=L, n_v=n_v, delta=delta, rd_mode=rd)
    state, _ = _state_and_bits(cfg, B)
    bits = jnp.stack([horizon.event_bits(KEY, state.step + i, state.tau.shape)
                      for i in range(K)])
    t1, s1 = ops.pdes_multistep(state.tau, bits, n_v=n_v, delta=delta,
                                rd_mode=rd)
    t2, s2 = ref.pdes_multistep_ref(state.tau, bits, n_v=n_v, delta=delta,
                                    rd_mode=rd)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    for k in s1:
        np.testing.assert_allclose(np.asarray(s1[k]), np.asarray(s2[k]),
                                   rtol=1e-6)


@pytest.mark.parametrize("L,n_v,delta,rd,B", SWEEP[:5])
def test_pdes_multistep_counter_matches_ref(L, n_v, delta, rd, B):
    """In-kernel event generation == host counter stream (bitwise)."""
    cfg = PDESConfig(L=L, n_v=n_v, delta=delta, rd_mode=rd)
    state, _ = _state_and_bits(cfg, B)
    ctr = jnp.array([[3, 5, 0, 0]], dtype=jnp.uint32)
    t1, s1 = ops.pdes_multistep_counter(state.tau, ctr, k_steps=6, n_v=n_v,
                                        delta=delta, rd_mode=rd)
    t2, s2 = ref.pdes_multistep_counter_ref(state.tau, ctr, k_steps=6,
                                            n_v=n_v, delta=delta, rd_mode=rd)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    for k in s1:
        np.testing.assert_allclose(np.asarray(s1[k]), np.asarray(s2[k]),
                                   rtol=1e-6)


@pytest.mark.parametrize("block_b", [1, 2, 8])
def test_block_size_invariance(block_b):
    """Tiling must not change results."""
    cfg = PDESConfig(L=64, n_v=2, delta=4.0)
    state, bits = _state_and_bits(cfg, 8)
    ta, _ = ops.step_ring(state.tau, bits, cfg, block_b=8)
    tb, _ = ops.step_ring(state.tau, bits, cfg, block_b=block_b)
    np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))


@pytest.mark.parametrize("block_b", [1, 2, 8])
def test_counter_kernel_block_invariance(block_b):
    """The counter kernel derives trial indices from program_id * block_b —
    tiling must not shift the event stream."""
    cfg = PDESConfig(L=64, n_v=2, delta=4.0)
    state, _ = _state_and_bits(cfg, 8)
    ctr = jnp.array([[11, 0, 4, 0]], dtype=jnp.uint32)   # nonzero b0 too
    ta, _ = ops.pdes_multistep_counter(state.tau, ctr, k_steps=4, n_v=2,
                                       delta=4.0, block_b=8)
    tb, _ = ops.pdes_multistep_counter(state.tau, ctr, k_steps=4, n_v=2,
                                       delta=4.0, block_b=block_b)
    np.testing.assert_array_equal(np.asarray(ta), np.asarray(tb))


@pytest.mark.parametrize("n_steps,k_fuse", [(5, 8), (16, 8), (37, 8), (24, 6)])
def test_simulate_equals_run(n_steps, k_fuse):
    """Kernel-path driver reproduces horizon.run stats and state exactly."""
    cfg = PDESConfig(L=64, n_v=4, delta=8.0)
    st0 = horizon.init_state(cfg, 8)
    key = jax.random.key(3)
    st_a, stats_a = horizon.run(st0, key, cfg, n_steps)
    st_b, out_b = ops.simulate(st0, key, cfg, n_steps, k_fuse=k_fuse)
    np.testing.assert_allclose(np.asarray(stats_a.utilization),
                               np.asarray(out_b["u"]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(stats_a.w2),
                               np.asarray(out_b["w2"]), rtol=1e-4, atol=1e-4)
    abs_a = np.asarray(st_a.tau) + np.asarray(st_a.offset)[:, None]
    abs_b = np.asarray(st_b.tau) + np.asarray(st_b.offset)[:, None]
    np.testing.assert_allclose(abs_a, abs_b, rtol=1e-5, atol=1e-4)


def test_vmem_budget_helper():
    cfg = PDESConfig(L=16384, n_v=1)
    bb = ops.pick_block_b(cfg)
    assert bb >= 1
    assert ops.vmem_bytes(cfg, bb) <= 8 << 20

"""Attention paths vs a naive dense oracle: values + gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models.flash import flash_attention

KEY = jax.random.key(0)


def naive(q, k, v, causal=True, window=None, softcap=None):
    B, Sq, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32) * D**-0.5
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    iq = jnp.arange(Sq)[:, None]
    ik = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((Sq, k.shape[1]), bool)
    if causal:
        mask &= iq >= ik
    if window is not None:
        mask &= ik > iq - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, D)


def qkv(B=2, S=192, H=8, KH=4, D=32):
    q = jax.random.normal(jax.random.fold_in(KEY, 1), (B, S, H, D))
    k = jax.random.normal(jax.random.fold_in(KEY, 2), (B, S, KH, D))
    v = jax.random.normal(jax.random.fold_in(KEY, 3), (B, S, KH, D))
    return q, k, v


CASES = [("causal", dict()), ("softcap", dict(softcap=30.0)),
         ("window", dict(window=48)), ("bidir", dict(causal=False))]


@pytest.mark.parametrize("name,kw", CASES)
def test_blockwise_matches_naive(name, kw):
    q, k, v = qkv()
    out = A.blockwise_attention(q, k, v, causal=kw.get("causal", True),
                                window=kw.get("window"),
                                softcap=kw.get("softcap"),
                                q_block=64, k_block=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(naive(q, k, v, **kw)),
                               rtol=2e-5, atol=2e-5)


def test_packed_matches_naive():
    q, k, v = qkv()
    out = A.packed_causal_attention(q, k, v, q_block=64, k_block=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(naive(q, k, v)),
                               rtol=2e-5, atol=2e-5)


def test_swa_matches_naive():
    q, k, v = qkv(S=256)
    out = A.swa_attention(q, k, v, window=48, q_block=64)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(naive(q, k, v, window=48)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("name,kw", CASES + [("window100", dict(window=100))])
def test_flash_values_and_grads(name, kw):
    q, k, v = qkv(S=256)

    def f(q, k, v):
        return flash_attention(q, k, v, kw.get("causal", True),
                               kw.get("window"), kw.get("softcap"), 64, 64, 0)

    out = f(q, k, v)
    ref = naive(q, k, v, **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    g1 = jax.grad(lambda *a: (f(*a) ** 2).sum(), argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lambda *a: (naive(*a, **kw) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)


def test_decode_matches_naive():
    q, k, v = qkv(S=128)
    pos = 100
    out = A.decode_attention(q[:, :1], k, v, pos)
    ref = naive(q[:, :1], k[:, :pos], v[:, :pos], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    outw = A.decode_attention(q[:, :1], k, v, pos, window=16)
    refw = naive(q[:, :1], k[:, pos - 16:pos], v[:, pos - 16:pos],
                 causal=False)
    np.testing.assert_allclose(np.asarray(outw), np.asarray(refw),
                               rtol=2e-5, atol=2e-5)


def test_flash_q_offset():
    """Prefill continuation: q_offset shifts causal positions."""
    q, k, v = qkv(S=128)
    q_tail = q[:, 64:]
    out = flash_attention(q_tail, k, v, True, None, None, 64, 64, 64)
    full = naive(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(full[:, 64:]),
                               rtol=2e-5, atol=2e-5)

"""Checkpoint/restore, elastic resharding, and failure-recovery training."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import DataConfig, make_batch
from repro.optim.adamw import AdamWConfig
from repro.train import checkpoint
from repro.train.fault import FaultInjector, RecoveryConfig, TrainController
from repro.train.train_step import init_train_state, make_train_step


def _tiny_setup(tmp, steps=30, seed=0):
    cfg = dataclasses.replace(get_config("llama3.2-1b").reduced(),
                              n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                              head_dim=16, d_ff=64, vocab_size=128,
                              q_block=16, k_block=16, ce_chunk=16)
    model, step = make_train_step(cfg, None, AdamWConfig(
        peak_lr=1e-3, warmup_steps=2, total_steps=steps))
    state = init_train_state(model, jax.random.key(seed))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=2)
    return cfg, jax.jit(step), state, lambda s: make_batch(dc, s)


def test_checkpoint_roundtrip(tmp_path):
    _, step, state, data = _tiny_setup(tmp_path)
    state, _ = step(state, data(0))
    p = tmp_path / "ck"
    checkpoint.save(state, p, step=1)
    restored = checkpoint.restore(p, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step(tmp_path):
    _, step, state, data = _tiny_setup(tmp_path)
    assert checkpoint.latest_step(tmp_path) is None
    checkpoint.save(state, tmp_path / "step_5", step=5)
    checkpoint.save(state, tmp_path / "step_10", step=10)
    assert checkpoint.latest_step(tmp_path) == 10


def test_recovery_resumes_and_matches_uninterrupted_run(tmp_path):
    """Kill training mid-run; recovered run must equal the failure-free run
    (deterministic pipeline + checkpointed state)."""
    _, step, state0, data = _tiny_setup(tmp_path, steps=20)

    ctl_plain = TrainController(
        step, jax.tree.map(jnp.copy, state0), data,
        RecoveryConfig(ckpt_dir=str(tmp_path / "a"), ckpt_every=5))
    log_a = ctl_plain.run(15)

    ctl_fail = TrainController(
        step, jax.tree.map(jnp.copy, state0), data,
        RecoveryConfig(ckpt_dir=str(tmp_path / "b"), ckpt_every=5),
        injector=FaultInjector(fail_at_steps=(7, 12)))
    log_b = ctl_fail.run(15)
    assert ctl_fail.restarts == 2
    # final loss identical: replayed steps are bit-deterministic
    np.testing.assert_allclose(log_a[-1]["loss"], log_b[-1]["loss"],
                               rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(ctl_plain.state["step"]), np.asarray(ctl_fail.state["step"]))


def test_elastic_restore_new_sharding(tmp_path):
    """Restore onto explicit (trivial single-device) shardings — the elastic
    path used when the mesh shape changes between runs."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import make_mesh
    _, step, state, data = _tiny_setup(tmp_path)
    p = tmp_path / "ck"
    checkpoint.save(state, p, step=0)
    mesh = make_mesh((1,), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored = checkpoint.restore(p, state, shardings)
    s2, _ = step(restored, data(0))
    assert np.isfinite(float(jax.tree.leaves(s2["opt"])[0].sum()))


def test_max_restarts_exceeded(tmp_path):
    _, step, state, data = _tiny_setup(tmp_path)
    ctl = TrainController(
        step, state, data,
        RecoveryConfig(ckpt_dir=str(tmp_path / "c"), ckpt_every=100),
        injector=FaultInjector(fail_at_steps=(1,)))
    # failure at step 1 with no checkpoint -> restarts from 0, REPLAYS the
    # lost step (log grows by one), and completes all 5 steps
    log = ctl.run(5)
    assert len(log) == 6                      # one replayed entry
    assert ctl.step == 5 and ctl.restarts == 1

"""Core PDES semantics: update rules, measurement identities, scaling fits."""
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PDESConfig, horizon, measurement, scaling, theory

KEY = jax.random.key(42)


class TestUpdateRules:
    def test_initial_utilization_is_one(self):
        """Fully synchronized start: every PE updates at t=0 (Sec. IV.B)."""
        cfg = PDESConfig(L=32, n_v=1)
        st, stats = horizon.run(horizon.init_state(cfg, 8), KEY, cfg, 1)
        np.testing.assert_allclose(np.asarray(stats.utilization[0]), 1.0)

    def test_delta_zero_serializes(self):
        """Δ=0: only the slowest PE may update -> u -> 1/L (Sec. IV.A)."""
        cfg = PDESConfig(L=16, n_v=1, delta=0.0)
        st = horizon.burn_in(horizon.init_state(cfg, 16), KEY, cfg, 50)
        _, stats = horizon.run_mean(st, jax.random.key(1), cfg, 400)
        u = float(np.asarray(stats.utilization).mean())
        assert abs(u - 1.0 / 16) < 0.02, u

    def test_rd_infinite_window_is_full_utilization(self):
        """RD + Δ=inf: no constraints at all -> u = 100%."""
        cfg = PDESConfig(L=32, n_v=1, rd_mode=True)
        _, stats = horizon.run(horizon.init_state(cfg, 4), KEY, cfg, 20)
        np.testing.assert_allclose(np.asarray(stats.utilization), 1.0)

    def test_tau_monotone_and_causality(self):
        """Virtual times never decrease; updates never violate Eq. (1)."""
        cfg = PDESConfig(L=64, n_v=3, delta=5.0)
        state = horizon.init_state(cfg, 4)
        key = KEY
        tau_abs = np.zeros((4, 64))
        for t in range(30):
            bits = horizon.event_bits(key, state.step, state.tau.shape)
            is_l, is_r, eta = horizon.decode_events(bits, cfg)
            tau, upd, gvt = horizon.step_core(state.tau, is_l, is_r, eta, cfg)
            tau_np, upd_np = np.asarray(state.tau), np.asarray(upd)
            # causality: an updated left-border PE had tau <= left neighbor
            viol_l = upd_np & np.asarray(is_l) & (tau_np > np.roll(tau_np, 1, -1))
            viol_r = upd_np & np.asarray(is_r) & (tau_np > np.roll(tau_np, -1, -1))
            assert not viol_l.any() and not viol_r.any()
            assert (np.asarray(tau) >= tau_np - 1e-6).all()
            state, _ = horizon._one_step(state, key, cfg)

    def test_window_bound_spread(self):
        """Δ-window bounds the horizon spread by Δ + O(one increment)."""
        cfg = PDESConfig(L=128, n_v=1, delta=3.0)
        st = horizon.burn_in(horizon.init_state(cfg, 8), KEY, cfg, 500)
        tau = np.asarray(st.tau)
        spread = tau.max(axis=1) - tau.min(axis=1)
        # increments are Exp(1); allow a generous tail
        assert (spread <= 3.0 + 12.0).all(), spread.max()

    def test_border_both_stricter(self):
        """Checking both neighbors can only lower utilization."""
        u = {}
        for both in (False, True):
            cfg = PDESConfig(L=64, n_v=4, border_both=both)
            st = horizon.burn_in(horizon.init_state(cfg, 16), KEY, cfg, 300)
            _, stats = horizon.run_mean(st, jax.random.key(2), cfg, 300)
            u[both] = float(np.asarray(stats.utilization).mean())
        assert u[True] <= u[False] + 0.01


class TestMeasurement:
    def test_simplex_identities(self):
        """Eqs. (17)-(18): group decomposition recombines exactly."""
        tau = jax.random.exponential(KEY, (8, 100)) * 5
        g = measurement.group_decomposition(tau)
        np.testing.assert_allclose(
            np.asarray(measurement.recombine_w2(g)),
            np.asarray(measurement.width(tau)) ** 2, rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(measurement.recombine_wa(g)),
            np.asarray(measurement.width_abs(tau)), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(g.f_slow + g.f_fast), 1.0)

    def test_extremes_and_spread(self):
        tau = jnp.array([[0.0, 1.0, 5.0, 2.0]])
        above, below = measurement.extreme_fluctuations(tau)
        assert float(above[0]) == 3.0 and float(below[0]) == 2.0
        assert float(measurement.spread(tau)[0]) == 5.0

    def test_progress_rate(self):
        g = jnp.arange(100, dtype=jnp.float32)[:, None] * 0.25
        r = measurement.progress_rate(g)
        np.testing.assert_allclose(np.asarray(r), 0.25, rtol=1e-5)


class TestScaling:
    def test_krug_meakin_recovery(self):
        Ls = np.array([16, 32, 64, 128, 256, 512])
        u = theory.krug_meakin_u(Ls, u_inf=0.2464, const=0.31)
        ex = scaling.krug_meakin_extrapolate(Ls, u)
        assert abs(ex.u_inf - 0.2464) < 1e-6

    def test_rational_extrapolation(self):
        Ls = np.array([8, 16, 32, 64, 128, 256, 512, 1024])
        u = 0.3 + 0.5 / Ls + 2.0 / Ls**2
        ex = scaling.rational_extrapolate(Ls, u)
        assert abs(ex.u_inf - 0.3) < 5e-3, ex

    def test_power_law_fit(self):
        t = np.arange(1, 1000)
        w2 = 3.0 * t ** (2 / 3)
        beta, resid = scaling.growth_exponent(t, w2)
        assert abs(beta - 1 / 3) < 0.01 and resid < 1e-6

    def test_roughness_exponent(self):
        Ls = np.array([16, 32, 64, 128])
        alpha, _ = scaling.roughness_exponent(Ls, 0.1 * Ls ** 1.0)
        assert abs(alpha - 0.5) < 0.01


class TestTheory:
    def test_u_kpz_limits(self):
        assert abs(theory.u_kpz(1) - 0.2475) < 1e-3
        assert theory.u_kpz(1e9) > 0.99

    def test_u_rd_limits(self):
        assert theory.u_rd(0.0) == 0.0
        assert theory.u_rd(1e9) > 0.99
        # monotone increasing in Δ
        d = np.array([0.5, 1, 2, 5, 10, 50, 100])
        u = theory.u_rd(d)
        assert (np.diff(u) > 0).all()

    def test_p_exponent_limits(self):
        assert theory.p_exponent(0.0) == 0.0
        assert theory.p_exponent(1e12) > 0.999

    def test_composite_delta_inf_equals_kpz(self):
        n = np.array([1.0, 10.0, 100.0])
        np.testing.assert_allclose(theory.u_composite(n, np.inf),
                                   theory.u_kpz(n))

    def test_mean_field_eq13(self):
        # u = 1 / (1 + (δ - 2/NV) p_w); sanity at p_w = 0 -> u = 1
        assert theory.u_kpz_mean_field(10, 3.0, 0.0) == 1.0
        assert theory.u_kpz_mean_field(10, 3.0, 0.5) < 1.0

    def test_extreme_delta_no_warnings(self):
        """Regression: Δ -> 0 and Δ -> inf limits are exact and warning-free.

        The rational fits used to evaluate ``c/Δ**e`` at Δ=0, producing an
        inf - inf NaN (RuntimeWarning) before the final mask; the limits are
        now taken analytically on a finite-domain guard.
        """
        import warnings
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            for fp in (True, False):
                assert theory.u_rd(0.0, fp) == 0.0
                assert theory.u_rd(math.inf, fp) == 1.0
                d = np.array([0.0, 1e-12, 1.0, 1e9, math.inf])
                u = theory.u_rd(d, fp)
                assert np.isfinite(u).all() and (np.diff(u) >= 0).all()
            assert theory.p_exponent(0.0) == 0.0
            assert theory.p_exponent(math.inf) == 1.0
            for nv in (1, 10, 100):
                assert theory.p_exponent(0.0, nv) == 0.0
                assert theory.p_exponent(math.inf, nv) == 1.0
                p = theory.p_exponent(np.array([0.0, 1e-9, 1e9, math.inf]), nv)
                assert np.isfinite(p).all()
            # composite surface stays finite over the whole (N_V, Δ) domain
            u = theory.u_composite(np.array([1.0, 10.0]),
                                   np.array([0.0, math.inf]))
            assert np.isfinite(u).all()
            # bad inputs surface as NaN, never as u = 1
            assert np.isnan(theory.u_rd(np.nan))
            assert np.isnan(theory.u_rd(-1.0))
            assert np.isnan(theory.p_exponent(-2.0, 10))

"""Telemetry tests: registry semantics, goldens, off-path bit-identity.

The contract under test (``repro.obs``, ISSUE 10): instrumentation is
strictly off-path — it observes host-side values the instrumented code
already materialized, so telemetry-on responses are bit-identical to
telemetry-off responses (checked single-device in-process and on an
8-fake-device mesh in a subprocess).  Exposition is deterministic: the
Prometheus text and Chrome-trace JSON renderings are golden-filed under a
fixed clock and re-render byte-identically.  ``python -m repro.obs
summarize --check`` (the CI gate) accepts what the daemon writes and
rejects empty snapshots, missing paper observables, and non-nesting
spans.
"""
import itertools
import json
import math
import os
import subprocess
import sys
import textwrap

import pytest

from repro.obs import (MetricsRegistry, Telemetry, TraceRecorder,
                       append_jsonl, current_tracer, set_tracer, span,
                       to_prometheus, write_snapshot)
from repro.obs.summarize import (REQUIRED_SERVICE_SERIES, check_metrics,
                                 check_trace, load_any)
from repro.obs.summarize import main as summarize_main

GOLDEN = os.path.join(os.path.dirname(os.path.abspath(__file__)), "golden")

# the shared single-device pass shape of the service telemetry tests
COMMON = dict(Ls=(16,), n_vs=(2,), replicas=4, n_steps=32, burn_in=16,
              backend="pallas_multistep", k_fuse=8)


# ---------------------------------------------------------------------------
# metrics core
# ---------------------------------------------------------------------------


def test_counter_monotone():
    reg = MetricsRegistry()
    c = reg.counter("c", "help text")
    c.inc()
    c.inc(2.5, requester="alice")
    assert c.value() == 1.0
    assert c.value(requester="alice") == 2.5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_counter_set_total_mirrors_external_ledger():
    # the service syncs ServiceStats fields via set_total: monotone, and a
    # regression (ledger went backwards) is a loud programming error
    reg = MetricsRegistry()
    c = reg.counter("c")
    c.set_total(5)
    c.set_total(5)
    c.set_total(9)
    assert c.value() == 9.0
    with pytest.raises(ValueError):
        c.set_total(3)


def test_gauge_goes_both_ways():
    g = MetricsRegistry().gauge("g")
    g.set(4.0)
    g.set(1.5)
    assert g.value() == 1.5
    assert g.value(other="labels") == 0.0


def test_histogram_counts_and_validation():
    reg = MetricsRegistry()
    h = reg.histogram("h", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 3.0, 100.0):
        h.observe(v)
    (series,) = h.series.values()
    assert series["counts"] == [2, 0, 1, 1]      # le=1 is inclusive
    assert series["count"] == 4 == h.count()
    assert series["sum"] == pytest.approx(104.5)
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=())
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=(1.0, 1.0))    # duplicate bound


def test_registry_get_or_create_and_kind_clash():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")
    assert len(reg) == 1


def test_series_materialize_on_first_update_only():
    # "series present in a snapshot" must mean the instrumented path ran —
    # merely creating instruments exposes nothing
    reg = MetricsRegistry(clock=lambda: 0.0)
    reg.counter("never_used")
    reg.histogram("never_observed")
    assert reg.snapshot()["series"] == []
    assert to_prometheus(reg) == ""


# ---------------------------------------------------------------------------
# exposition goldens (fixed clock -> byte-stable)
# ---------------------------------------------------------------------------


def _golden_registry() -> MetricsRegistry:
    reg = MetricsRegistry(clock=lambda: 1700000000.0)
    req = reg.counter("repro_service_requests", "wire requests accepted")
    req.inc(5)
    served = reg.counter("repro_service_served_rows",
                         "rows returned, by requester", unit="rows")
    served.inc(8, requester="alice")
    served.inc(4, requester="bob")
    reg.gauge("repro_service_coalescing_ratio",
              "rows requested / rows computed").set(1.5)
    u = reg.histogram("repro_pass_u", "per-pass mean utilization",
                      buckets=(0.25, 0.5, 1.0))
    u.observe(0.125)
    u.observe(0.75)
    reg.histogram("repro_pass_w2", "per-pass mean squared width",
                  unit="tau^2", buckets=(1.0, 4.0, 16.0)).observe(2.5)
    reg.histogram("repro_pass_window_occupancy", "spread / Delta",
                  buckets=(0.5, 1.0)).observe(0.8)
    return reg


def test_prometheus_golden():
    text = to_prometheus(_golden_registry())
    with open(os.path.join(GOLDEN, "obs_metrics.prom")) as fh:
        assert text == fh.read()
    # deterministic: re-rendering an unchanged registry is byte-identical
    assert to_prometheus(_golden_registry()) == text


def test_prometheus_label_escaping():
    reg = MetricsRegistry()
    reg.counter("c").inc(1, path='a"b\\c\nd')
    line = to_prometheus(reg).splitlines()[-1]
    assert line == 'c{path="a\\"b\\\\c\\nd"} 1'


def _step_clock(step=1.0):
    counter = itertools.count()
    return lambda: step * next(counter)


def _golden_tracer() -> TraceRecorder:
    tr = TraceRecorder(clock=_step_clock(), pid=1)   # ticks 0, 1, 2, ... s
    with tr.span("round", cat="daemon", args={"round": 1}):
        with tr.span("pass", cat="service") as sp:
            sp.args.update(n_rows=12, rows_burned=12, rows_from_cache=0)
        with tr.span("reduce"):
            pass
    return tr


def test_trace_golden(tmp_path):
    path = tmp_path / "trace.json"
    _golden_tracer().save(path)
    with open(path) as fh, \
            open(os.path.join(GOLDEN, "obs_trace.json")) as golden:
        assert fh.read() == golden.read()
    assert check_trace(load_any(path)[1]) == []


def test_trace_span_error_annotation():
    tr = TraceRecorder(clock=_step_clock(), pid=1)
    with pytest.raises(RuntimeError):
        with tr.span("boom"):
            raise RuntimeError("x")
    (ev,) = tr.events
    assert ev["args"]["error"] == "RuntimeError"


def test_ambient_tracer_helper():
    assert current_tracer() is None
    with span("nothing") as sp:       # no tracer installed: yields None
        assert sp is None
    tr = TraceRecorder()
    prev = set_tracer(tr)
    try:
        assert prev is None
        assert current_tracer() is tr
        with span("real") as sp:
            assert sp is not None
        assert [e["name"] for e in tr.events] == ["real"]
    finally:
        set_tracer(prev)
    assert current_tracer() is None


# ---------------------------------------------------------------------------
# sinks + snapshot files
# ---------------------------------------------------------------------------


def test_jsonl_sink_appends_and_loads_last(tmp_path):
    path = tmp_path / "sink.jsonl"
    reg = _golden_registry()
    append_jsonl(reg, path)
    reg.counter("repro_service_requests").inc(1)
    append_jsonl(reg, path)
    assert len(path.read_text().splitlines()) == 2
    kind, snap = load_any(path)                  # last line wins
    assert kind == "metrics"
    (req,) = [s for s in snap["series"]
              if s["name"] == "repro_service_requests"]
    assert req["value"] == 6.0
    assert snap["ts"] == 1700000000.0


def test_write_snapshot_atomic_pair(tmp_path):
    reg = _golden_registry()
    snap = write_snapshot(reg, tmp_path / "metrics")
    d = tmp_path / "metrics"
    assert sorted(os.listdir(d)) == ["metrics.json", "metrics.prom"]
    assert (d / "metrics.prom").read_text() == to_prometheus(reg)
    assert json.loads((d / "metrics.json").read_text()) == snap
    kind, loaded = load_any(d)                   # dir resolves to the json
    assert kind == "metrics" and loaded == snap


# ---------------------------------------------------------------------------
# summarize --check: the CI gate
# ---------------------------------------------------------------------------


def test_check_rejects_empty_and_missing_observables():
    assert check_metrics({"series": []}) == ["metrics snapshot has no series"]
    # a service-produced snapshot (any repro_service_*) must carry the live
    # paper observables with >=1 observation each
    reg = MetricsRegistry(clock=lambda: 0.0)
    reg.counter("repro_service_requests").inc(1)
    problems = check_metrics(reg.snapshot())
    assert len(problems) == len(REQUIRED_SERVICE_SERIES)
    for req, p in zip(REQUIRED_SERVICE_SERIES, sorted(problems)):
        assert req in p
    # a non-service snapshot (e.g. bench-only) has no such requirement
    reg2 = MetricsRegistry(clock=lambda: 0.0)
    reg2.counter("repro_bench_calls").inc(1)
    assert check_metrics(reg2.snapshot()) == []


def test_check_rejects_inconsistent_histogram():
    snap = {"series": [{"name": "h", "type": "histogram",
                        "buckets": [1.0], "counts": [1, 0], "count": 3,
                        "sum": 0.5}]}
    (p,) = check_metrics(snap)
    assert "counts sum" in p


def test_check_rejects_non_nesting_spans():
    base = {"cat": "t", "ph": "X", "pid": 1, "tid": 1}
    ok = {"traceEvents": [dict(base, name="outer", ts=0, dur=10),
                          dict(base, name="inner", ts=2, dur=3),
                          dict(base, name="later", ts=20, dur=5)]}
    assert check_trace(ok) == []
    bad = {"traceEvents": [dict(base, name="a", ts=0, dur=10),
                           dict(base, name="b", ts=5, dur=10)]}
    (p,) = check_trace(bad)
    assert "without nesting" in p
    assert check_trace({"traceEvents": []}) \
        == ["trace has no complete ('X') spans"]
    # other lanes are independent: the same overlap on two tids is fine
    two_lanes = {"traceEvents": [dict(base, name="a", ts=0, dur=10),
                                 dict(base, name="b", ts=5, dur=10,
                                      tid=2)]}
    assert check_trace(two_lanes) == []


def test_summarize_cli_roundtrip(tmp_path, capsys):
    mdir = tmp_path / "metrics"
    write_snapshot(_golden_registry(), mdir)
    tpath = tmp_path / "trace.json"
    _golden_tracer().save(tpath)
    assert summarize_main(["summarize", "--check", str(mdir),
                           str(tpath)]) == 0
    out = capsys.readouterr().out
    assert out.count("check ok") == 2
    assert "repro_pass_u" in out and "round" in out
    # an empty snapshot fails the gate
    empty = tmp_path / "empty"
    write_snapshot(MetricsRegistry(clock=lambda: 0.0), empty)
    assert summarize_main(["summarize", "--check", str(empty)]) == 1


# ---------------------------------------------------------------------------
# service integration: off-path bit-identity + live observables
# ---------------------------------------------------------------------------


def _serve_once(telemetry):
    from repro.experiments import WindowSweep
    from repro.service import SweepService
    spec = WindowSweep(deltas=(2.0, 4.0, math.inf), **COMMON)
    svc = SweepService(telemetry=telemetry)
    svc.submit(spec, requester="alice")
    (resp,) = svc.drain()
    assert resp.error is None
    return resp.result


def test_service_telemetry_is_off_path_bit_identical():
    pytest.importorskip("jax")
    tel = Telemetry(tracer=TraceRecorder())
    with_tel = _serve_once(tel)
    without = _serve_once(None)
    # float-equal records, not allclose: telemetry must not perturb results
    assert with_tel.records == without.records

    # live observables materialized: one histogram observation per pass
    snap = tel.registry.snapshot()
    assert check_metrics(snap) == []
    by_name = {}
    for s in snap["series"]:
        by_name.setdefault(s["name"], []).append(s)
    for req in ("repro_pass_u", "repro_pass_w2",
                "repro_pass_window_occupancy"):
        assert sum(s["count"] for s in by_name[req]) >= 1, req
    (served,) = by_name["repro_service_served_rows"]
    assert served["labels"] == {"requester": "alice"}

    # exactly one "pass" span, annotated with its CompatKey + provenance
    passes = [e for e in tel.tracer.events if e["name"] == "pass"]
    assert len(passes) == 1
    args = passes[0]["args"]
    assert args["L"] == 16 and args["n_v"] == 2
    assert args["backend"] == COMMON["backend"]
    assert args["n_rows"] == 3 * COMMON["replicas"]
    assert args["rows_burned"] + args["rows_from_cache"] == args["n_rows"]
    assert args["requesters"] == ["alice"]
    assert check_trace(tel.tracer.to_dict()) == []


def test_service_stats_snapshot_diff():
    pytest.importorskip("jax")
    from repro.service.api import ServiceStats
    a = ServiceStats()
    a.n_requests, a.rows_computed = 3, 100
    snap = a.snapshot()
    a.n_requests, a.rows_computed = 5, 160
    d = a.diff(snap)
    assert (d.n_requests, d.rows_computed) == (2, 60)
    assert d.n_errors == 0
    assert snap.n_requests == 3            # snapshot is an isolated copy


def test_daemon_writes_snapshots_and_trace(tmp_path):
    pytest.importorskip("jax")
    from repro.experiments import WindowSweep
    from repro.service.daemon import DaemonConfig, serve_daemon
    from repro.service.wire import encode_request

    intake = tmp_path / "intake"
    intake.mkdir()
    spec = WindowSweep(deltas=(2.0, 4.0), **COMMON)
    (intake / "a.jsonl").write_text(
        json.dumps(encode_request(spec, "alice")) + "\n")
    cfg = DaemonConfig(intake_dir=str(intake),
                       out_path=str(tmp_path / "responses.jsonl"),
                       poll_interval_s=0.01, idle_exit_rounds=2,
                       metrics_dir=str(tmp_path / "metrics"),
                       trace_path=str(tmp_path / "trace.json"))
    lines = []
    stats = serve_daemon(cfg, log=lines.append)
    assert stats.n_requests == 1 and stats.n_errors == 0

    # per-round delta logging (satellite a): rates, not lifetime totals
    round_lines = [ln for ln in lines if ln.startswith("round ")]
    assert any("+1 request(s)" in ln and "1 pass(es)" in ln
               for ln in round_lines)

    # exposition: snapshot pair + trace on disk, and the CI gate passes
    mdir = tmp_path / "metrics"
    assert sorted(os.listdir(mdir)) == ["metrics.json", "metrics.prom"]
    assert summarize_main(["summarize", "--check", str(mdir),
                           str(tmp_path / "trace.json")]) == 0
    prom = (mdir / "metrics.prom").read_text()
    for name in (*REQUIRED_SERVICE_SERIES, "repro_daemon_rounds",
                 "repro_daemon_phase_seconds", "repro_service_queue_depth",
                 "repro_service_phase_seconds"):
        assert name in prom, name

    trace = json.loads((tmp_path / "trace.json").read_text())
    names = [e["name"] for e in trace["traceEvents"]]
    assert names.count("pass") == stats.n_passes == 1
    rounds = [e for e in trace["traceEvents"] if e["name"] == "round"]
    assert rounds and rounds[0]["args"]["n_passes"] == 1


def test_sweep_emits_phase_spans_under_ambient_tracer():
    pytest.importorskip("jax")
    from repro.experiments import WindowSweep, run_window_sweep
    spec = WindowSweep(deltas=(2.0,), **COMMON)
    baseline = run_window_sweep(spec)           # untraced
    tr = TraceRecorder()
    prev = set_tracer(tr)
    try:
        traced = run_window_sweep(spec)
    finally:
        set_tracer(prev)
    assert traced.records == baseline.records   # tracing is off-path too
    names = [e["name"] for e in tr.events]
    assert names.count("burn") == 1
    assert names.count("measure") == 1
    assert names.count("reduce") == 1
    (burn,) = [e for e in tr.events if e["name"] == "burn"]
    assert burn["args"]["rows"] == spec.n_trajectories
    assert burn["args"]["steps"] == COMMON["burn_in"]
    assert check_trace(tr.to_dict()) == []


# ---------------------------------------------------------------------------
# sharded mesh: bit-identity holds under telemetry on 8 fake devices
# ---------------------------------------------------------------------------

_MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json, math
    from repro.compat import make_mesh
    from repro.experiments import WindowSweep
    from repro.obs import Telemetry, TraceRecorder
    from repro.obs.summarize import check_metrics, check_trace
    from repro.service import SweepService

    def same(xs, ys):
        # float-equal, except the sharded backend's wa is NaN by contract
        # (see test_sharded_sweep) and NaN != NaN under dataclass equality
        def eq(x, y):
            if isinstance(x, float) and math.isnan(x):
                return isinstance(y, float) and math.isnan(y)
            return x == y
        return len(xs) == len(ys) and all(
            all(eq(a, b) for a, b in zip(dataclasses.astuple(x),
                                         dataclasses.astuple(y)))
            for x, y in zip(xs, ys))

    spec = WindowSweep(Ls=(16,), n_vs=(2,), deltas=(1.0, 2.0, 4.0, math.inf),
                       replicas=4, n_steps=16, burn_in=8,
                       backend="sharded", k_fuse=4)

    def serve(telemetry):
        svc = SweepService(mesh=make_mesh((2, 4), ("data", "model")),
                           telemetry=telemetry)
        svc.submit(spec, requester="alice")
        (resp,) = svc.drain()
        assert resp.error is None, resp.error
        return resp.result

    tel = Telemetry(tracer=TraceRecorder())
    with_tel = serve(tel)
    without = serve(None)
    passes = [e for e in tel.tracer.events if e["name"] == "pass"]
    print(json.dumps({
        "bit_identical": same(with_tel.records, without.records),
        "metrics_ok": check_metrics(tel.registry.snapshot()) == [],
        "trace_ok": check_trace(tel.tracer.to_dict()) == [],
        "n_pass_spans": len(passes),
        "pad": passes[0]["args"].get("n_pad", 0) if passes else -1,
    }))
""")


@pytest.mark.distributed
def test_sharded_service_telemetry_bit_identical():
    pytest.importorskip("jax")
    env = dict(os.environ, PYTHONPATH="src")
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    out = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                         capture_output=True, text=True, env=env, cwd=repo)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["bit_identical"]
    assert res["metrics_ok"] and res["trace_ok"]
    assert res["n_pass_spans"] == 1

"""Benchmark harness: one function per paper figure/table.

Prints ``name,us_per_call,derived`` CSV lines and writes full JSON records to
results/benchmarks/.  Ensemble sizes are scaled to a single-host CPU run
(documented per entry); all qualitative paper claims (C1-C7, DESIGN.md §1)
are asserted here and summarized in EXPERIMENTS.md.

Every record carries machine metadata (jax version, device kind, Pallas
interpret-mode flag) so baselines are only ever compared apples-to-apples.

Run:  PYTHONPATH=src python -m benchmarks.run [--only fig2,eq8] [--fast]

Regression-gate mode (CI): compare a fresh run against committed baselines::

    python -m benchmarks.run --check results/benchmarks --tolerance 0.25

re-runs every benchmark found in the baseline file/directory (intersected
with ``--only``) and fails if a gate metric regresses beyond the tolerance.
Benches that publish a hardware-portable ``gate`` ratio (e.g. the fused
kernel's speedup over the reference scan) are gated on that ratio; the rest
fall back to wall time, which is only compared when the machine metadata
matches the baseline.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import pathlib
import subprocess
import sys
import tempfile
import textwrap
import time

import numpy as np

from repro.obs.trace import TraceRecorder, set_tracer

OUT = pathlib.Path("results/benchmarks")

#: ambient span recorder, installed by ``main``.  Every bench JSON gets a
#: ``phases_us`` burn/measure/reduce breakdown from the spans the library
#: emits (``ensemble.steady_state``, ``sweep.run_window_sweep``); pass
#: ``--trace FILE`` to also keep the full Chrome-trace JSON.  Gate ratios
#: are computed exactly as before — the breakdown is payload-only.
_TRACER: TraceRecorder | None = None
_PHASE_MARK = {"n": 0}


def _phase_breakdown() -> dict | None:
    """Sum burn/measure/reduce span µs recorded since the previous call.

    Each ``_emit`` consumes the spans its bench produced, so concurrent
    phases never leak across records.  Subprocess benches (pdes_comm,
    window_sweep_sharded) trace nothing here and simply carry no
    breakdown.
    """
    if _TRACER is None:
        return None
    events = _TRACER.events[_PHASE_MARK["n"]:]
    _PHASE_MARK["n"] += len(events)
    out: dict[str, float] = {}
    for ev in events:
        if ev["name"] in ("burn", "measure", "reduce"):
            out[ev["name"]] = out.get(ev["name"], 0.0) + ev["dur"]
    return {k: round(v, 1) for k, v in out.items()} or None

#: Every bench in this harness validates Pallas paths in interpret mode on
#: CPU (the engine default); recorded in the metadata so a TPU baseline can
#: never be gated against a CPU run.
INTERPRET_MODE = True

#: CLI workload knobs of the current invocation (set by ``main``), stamped
#: into the metadata: a ``--fast`` or ``--backend``-narrowed run is a
#: different workload and must never be gated against a full-run baseline.
_RUN_CONFIG = {"fast": False, "cli_backend": None}


def machine_meta() -> dict:
    """Machine/runtime + workload metadata stamped into every result JSON.

    ``--check`` uses this to keep baseline comparisons apples-to-apples:
    gates are skipped when platform / device kind / interpret mode / CLI
    workload knobs differ from the baseline's.
    """
    import platform

    import jax
    dev = jax.devices()[0]
    return {
        "jax_version": jax.__version__,
        "platform": dev.platform,
        "device_kind": dev.device_kind,
        "interpret_mode": INTERPRET_MODE,
        # host identity: "cpu/cpu" is the same on every x86 box, so wall-time
        # gates additionally require the same hostname/core count — i.e. they
        # only ever fire on the machine that recorded the baseline.
        "hostname": platform.node(),
        "cpu_count": os.cpu_count(),
        "python_version": platform.python_version(),
        **_RUN_CONFIG,
    }


_ANALYSIS_VERDICT: dict | None = None


def analysis_verdict() -> dict:
    """Causality-linter verdict stamped into every bench record.

    Computed once per process (the linter itself caches per backend tuple);
    a crashed linter is recorded as a failing verdict rather than aborting
    the benchmark run — perf numbers from an unverified tree are still worth
    keeping, they just carry the stain.
    """
    global _ANALYSIS_VERDICT
    if _ANALYSIS_VERDICT is None:
        try:
            from repro.analysis import analysis_verdict as verdict
            _ANALYSIS_VERDICT = verdict()
        except Exception as e:  # pragma: no cover - defensive
            _ANALYSIS_VERDICT = {"ok": False, "error": repr(e)}
    return _ANALYSIS_VERDICT


def _emit(name: str, us_per_call: float, derived: str, payload: dict,
          gate: dict | None = None):
    """Print the CSV line and write the JSON record.

    ``gate`` optionally names a hardware-portable regression-gate metric,
    e.g. ``{"metric": "speedup", "value": 2.2, "higher_is_better": True}``;
    ``--check`` prefers it over raw wall time.  Every record also carries the
    causality-linter verdict (``analysis`` key) so a perf baseline can never
    silently come from a tree that violates the protocol invariants.
    """
    print(f"{name},{us_per_call:.1f},{derived}")
    OUT.mkdir(parents=True, exist_ok=True)
    payload = dict(payload, name=name, us_per_call=us_per_call,
                   derived=derived, meta=machine_meta(),
                   analysis=analysis_verdict())
    phases = _phase_breakdown()
    if phases is not None:
        payload["phases_us"] = phases
    if gate is not None:
        payload["gate"] = gate
    (OUT / f"{name}.json").write_text(json.dumps(payload, indent=1))


def _timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    return out, (time.time() - t0) * 1e6


# ---------------------------------------------------------------------------
# Fig. 2 — unconstrained utilization evolution reaches a nonzero steady state
# ---------------------------------------------------------------------------


def fig2_utilization_evolution(fast=False):
    from repro.core import PDESConfig, ensemble
    trials = 32 if fast else 64
    rows = {}
    t0 = time.time()
    for L in (10, 100, 1000):
        for nv in (1, 10, 100):
            cfg = PDESConfig(L=L, n_v=nv)
            ev = ensemble.width_evolution(cfg, n_steps=600 if fast else 1500,
                                          n_trials=trials, seed=L + nv)
            rows[f"L{L}_nv{nv}"] = {
                "u_first": float(ev["u"][0]),
                "u_steady": float(ev["u"][-200:].mean()),
            }
    # claims: u(0) = 1 (synchronized start), steady state > 0, grows with nv
    assert all(abs(r["u_first"] - 1.0) < 1e-6 for r in rows.values())
    assert all(r["u_steady"] > 0.1 for r in rows.values())
    assert rows["L1000_nv100"]["u_steady"] > rows["L1000_nv1"]["u_steady"]
    _emit("fig2_utilization_evolution", (time.time() - t0) * 1e6,
          f"u_steady(L=1000,nv=1)={rows['L1000_nv1']['u_steady']:.4f}", rows,
          gate={"metric": "u_steady_L1000_nv1",
                "value": rows["L1000_nv1"]["u_steady"],
                "higher_is_better": True})


# ---------------------------------------------------------------------------
# Eq. (8) / Fig. 2 — u_inf = 24.6461(7)% via Krug-Meakin extrapolation  [C1]
# ---------------------------------------------------------------------------


def eq8_uinf_extrapolation(fast=False):
    from repro.core import PDESConfig, ensemble, scaling, theory
    Ls = [16, 32, 64, 128, 256] + ([] if fast else [512])
    us, t0 = [], time.time()
    for L in Ls:
        ss = ensemble.steady_state(
            PDESConfig(L=L, n_v=1), n_trials=32 if fast else 64, seed=L,
            burn_in_steps=int(5 * L ** 1.5) + 500,
            measure_steps=2000 if fast else 6000)
        us.append(ss.utilization)
    ex = scaling.krug_meakin_extrapolate(Ls, us, alpha=0.5)
    err = abs(ex.u_inf - theory.U_INF_KPZ_NV1)
    rec = {"Ls": Ls, "u_L": us, "u_inf": ex.u_inf,
           "paper": theory.U_INF_KPZ_NV1, "abs_err": err,
           "const": ex.coeffs["const"]}
    assert err < 0.01, rec        # C1: within 1% absolute of 24.6461%
    _emit("eq8_uinf_extrapolation", (time.time() - t0) * 1e6,
          f"u_inf={ex.u_inf:.4f} (paper 0.2465, err {err:.4f})", rec,
          gate={"metric": "abs_err_u_inf", "value": err,
                "higher_is_better": False})


# ---------------------------------------------------------------------------
# Fig. 4 + Eqs. (6,7,9) — KPZ growth and roughness exponents            [C2,C3]
# ---------------------------------------------------------------------------


def fig4_kpz_exponents(fast=False):
    """KPZ exponents at single-host-reachable scales.

    The asymptotic KPZ values (beta = 1/3, alpha = 1/2) emerge slowly: at
    L <= a few thousand the *effective* exponents sit below them and rise
    monotonically with scale (well-known corrections to scaling; the paper's
    own values come from L up to 1e4, t up to 1e6).  We therefore check
    (a) the monotone approach, and (b) the correction-extrapolated values.
    """
    from repro.core import PDESConfig, ensemble, scaling
    t0 = time.time()
    # effective growth exponent over increasing time windows
    L = 1024 if fast else 2048
    ev = ensemble.width_evolution(PDESConfig(L=L, n_v=1),
                                  n_steps=3000 if fast else 4000,
                                  n_trials=16, seed=0)
    # windows stay well inside the growth regime: the measured crossover is
    # t_x ~ 1.5 L^{3/2} (≈12k steps at L=2048), and the local slope bends
    # down within a factor ~3 of t_x.
    windows = [(30, 120), (120, 600), (600, 3000)]
    betas = [scaling.fit_power_law(ev["t"], ev["w2"], lo, hi)[0] / 2
             for lo, hi in windows]
    # effective roughness exponent from successive saturated-width pairs
    Ls = [16, 32, 64, 128, 256]
    sats = []
    for Li in Ls:
        ss = ensemble.steady_state(
            PDESConfig(L=Li, n_v=1), n_trials=32, seed=Li,
            burn_in_steps=int(8 * Li ** 1.5) + 1000,
            measure_steps=1500 if fast else 3000)
        sats.append(ss.w2)
    alpha_pairs = [math.log(b / a) / math.log(2) / 2
                   for a, b in zip(sats, sats[1:])]
    # extrapolate alpha_eff against 1/sqrt(L): intercept ~ alpha_inf
    x = np.array([1 / math.sqrt(math.sqrt(a * b))
                  for a, b in zip(Ls, Ls[1:])])
    A = np.stack([np.ones_like(x), x], 1)
    alpha_inf = float(np.linalg.lstsq(A, np.array(alpha_pairs), rcond=None)[0][0])
    # large-N_V initial growth is RD-like (beta ~ 1/2)               [C3]
    ev_rd = ensemble.width_evolution(PDESConfig(L=256, n_v=100),
                                     n_steps=400, n_trials=32, seed=7)
    beta_rd, _ = scaling.growth_exponent(ev_rd["t"], ev_rd["w2"],
                                         fit_lo_frac=0.02, fit_hi_frac=0.3)
    rec = {"beta_eff_windows": betas, "alpha_eff_pairs": alpha_pairs,
           "alpha_extrapolated": alpha_inf, "beta_early_nv100": beta_rd,
           "w2_sat": dict(zip(map(str, Ls), sats))}
    # C2: effective exponents rise toward the KPZ values
    assert betas[-1] > betas[0] - 0.02 and 0.22 <= betas[-1] <= 0.45, rec
    assert all(b >= a - 0.03 for a, b in zip(alpha_pairs, alpha_pairs[1:])), rec
    assert 0.38 <= alpha_inf <= 0.62, rec
    # C3: early growth at large N_V is RD-like, well above the KPZ beta
    assert beta_rd > 0.4, rec
    _emit("fig4_kpz_exponents", (time.time() - t0) * 1e6,
          f"beta_eff={betas[-1]:.3f}->1/3, alpha_pairs "
          f"{alpha_pairs[0]:.2f}->{alpha_pairs[-1]:.2f}, "
          f"alpha_inf={alpha_inf:.2f} (KPZ 0.5), beta_rd={beta_rd:.2f}", rec,
          gate={"metric": "beta_eff_late_window", "value": betas[-1],
                "higher_is_better": True})


# ---------------------------------------------------------------------------
# Fig. 5 — constrained utilization vs system size; RD limit             [C5]
# ---------------------------------------------------------------------------


def fig5_util_vs_L(fast=False):
    from repro.core import PDESConfig, ensemble
    t0 = time.time()
    Ls = [16, 32, 64, 128] + ([] if fast else [256])
    out = {}
    for delta in (10.0, 100.0):
        for nv in (1, 10, 100, "rd"):
            us = []
            for L in Ls:
                cfg = PDESConfig(L=L, n_v=1 if nv == "rd" else nv,
                                 delta=delta, rd_mode=(nv == "rd"))
                ss = ensemble.steady_state(cfg, n_trials=32, seed=L)
                us.append(ss.utilization)
            out[f"d{delta}_nv{nv}"] = dict(zip(map(str, Ls), us))
    # C5: for fixed L, u grows with N_V toward the RD curve
    for delta in (10.0, 100.0):
        u1 = out[f"d{delta}_nv1"][str(Ls[-1])]
        u100 = out[f"d{delta}_nv100"][str(Ls[-1])]
        urd = out[f"d{delta}_nvrd"][str(Ls[-1])]
        assert u1 < u100 <= urd + 0.03, (delta, u1, u100, urd)
    # gate: the N_V=100 over N_V=1 utilization lift at the largest L, Δ=10 —
    # a pure physics ratio (paper's central "many volatilities help" effect)
    lift = (out["d10.0_nv100"][str(Ls[-1])]
            / max(out["d10.0_nv1"][str(Ls[-1])], 1e-9))
    _emit("fig5_util_vs_L", (time.time() - t0) * 1e6,
          f"u(L=128,d=10): nv1={out['d10.0_nv1']['128']:.3f} "
          f"nv100={out['d10.0_nv100']['128']:.3f} "
          f"rd={out['d10.0_nvrd']['128']:.3f}", out,
          gate={"metric": "u_lift_nv100_over_nv1_d10", "value": lift,
                "higher_is_better": True})


# ---------------------------------------------------------------------------
# Fig. 6 + Appendix — u_inf(N_V, Δ) surface vs fits A.1/A.2/Eq.(12)     [C6]
# ---------------------------------------------------------------------------


def fig6_uinf_surface(fast=False):
    from repro.core import PDESConfig, ensemble, scaling, theory
    t0 = time.time()
    Ls = [64, 128, 256, 512] + ([] if fast else [1024, 2048])
    grid = {}
    for delta in (1.0, 10.0, 100.0):
        for nv in (1, 10, 100, "rd"):
            us = []
            for L in Ls:
                cfg = PDESConfig(L=L, n_v=1 if nv == "rd" else nv,
                                 delta=delta, rd_mode=(nv == "rd"))
                ss = ensemble.steady_state(
                    cfg, n_trials=16, seed=L,
                    burn_in_steps=None, measure_steps=1200)
                us.append(ss.utilization)
            ex = scaling.rational_extrapolate(Ls, us)
            nv_eff = 1e8 if nv == "rd" else nv
            pred = float(theory.u_composite(nv_eff, delta))
            grid[f"d{delta}_nv{nv}"] = {
                "u_inf": ex.u_inf, "paper_fit": pred,
                "abs_err": abs(ex.u_inf - pred), "u_L": us}
    errs = [v["abs_err"] for v in grid.values()]
    rec = {"grid": grid, "max_abs_err": max(errs),
           "mean_abs_err": float(np.mean(errs))}
    # C6: paper fit (12) is ±5-10%; finite-L extrapolation adds its own error
    assert rec["mean_abs_err"] < 0.08, rec["mean_abs_err"]
    _emit("fig6_uinf_surface", (time.time() - t0) * 1e6,
          f"mean|u_inf - fit|={rec['mean_abs_err']:.3f} "
          f"max={rec['max_abs_err']:.3f}", rec,
          gate={"metric": "mean_abs_err_vs_fit", "value": rec["mean_abs_err"],
                "higher_is_better": False})


# ---------------------------------------------------------------------------
# Figs. 7-9 — Δ-window bounds the width for any system size             [C4]
# ---------------------------------------------------------------------------


def fig9_width_saturation(fast=False):
    from repro.core import PDESConfig, ensemble
    t0 = time.time()
    Ls = [32, 64, 128, 256] + ([] if fast else [512])
    out = {}
    for delta in (1.0, 5.0, 10.0, 100.0):
        for nv in (1, 10):
            ws, was = [], []
            for L in Ls:
                ss = ensemble.steady_state(
                    PDESConfig(L=L, n_v=nv, delta=delta),
                    n_trials=16, seed=L)
                ws.append(ss.w)
                was.append(ss.wa)
            out[f"d{delta}_nv{nv}"] = {"w": ws, "wa": was}
            # C4: width bounded by O(Δ) for every L ...
            assert max(ws) <= delta + 4.0, (delta, nv, ws)
            # ... and saturates to a Δ-ceiling: once the unconstrained KPZ
            # width would exceed the window, w(L) flattens (<=12% change per
            # L-doubling at the top end) instead of growing as sqrt(L).
            if ws[-1] > 0.8 * delta:
                assert abs(ws[-1] - ws[-2]) <= 0.12 * ws[-2] + 0.05, \
                    (delta, nv, ws)
            else:                         # far from the ceiling: bounded rise
                assert ws[-1] <= ws[0] * math.sqrt(Ls[-1] / Ls[0]), \
                    (delta, nv, ws)
    # contrast: unconstrained width DOES grow with L (the paper's Fig. 4)
    w_unc = [ensemble.steady_state(PDESConfig(L=L, n_v=1), n_trials=8,
                                   seed=L).w for L in (32, 128)]
    assert w_unc[1] > w_unc[0] * 1.3
    rec = dict(out, Ls=Ls, w_unconstrained=w_unc)
    # gate: saturated width over the window size at Δ=10, largest L — the
    # paper's measurability claim is exactly that this ratio stays O(1)
    w_over_delta = out["d10.0_nv1"]["w"][-1] / 10.0
    _emit("fig9_width_saturation", (time.time() - t0) * 1e6,
          f"w_sat(d=10,nv=1): {out['d10.0_nv1']['w'][0]:.2f}->"
          f"{out['d10.0_nv1']['w'][-1]:.2f} over L={Ls[0]}->{Ls[-1]} "
          f"(Δ-ceiling); unconstrained {w_unc[0]:.2f}->{w_unc[1]:.2f}", rec,
          gate={"metric": "w_sat_over_delta_d10", "value": w_over_delta,
                "higher_is_better": False})


# ---------------------------------------------------------------------------
# Fig. 10 — slow/fast simplex decomposition; double-peak transient      [C7]
# ---------------------------------------------------------------------------


def fig10_slow_fast(fast=False):
    import jax
    from repro.core import (PDESConfig, group_decomposition, horizon,
                            recombine_w2, recombine_wa)
    t0 = time.time()
    cfg = PDESConfig(L=1000, n_v=1000, delta=10.0)
    n_steps = 300 if fast else 500
    state = horizon.init_state(cfg, 16)
    key = jax.random.key(0)
    series = {"f_slow": [], "wa_slow": [], "wa_fast": [], "wa": [], "u": []}
    for t in range(n_steps):
        state, stats = horizon.run(state, key, cfg, 1)
        g = group_decomposition(state.tau)
        series["f_slow"].append(float(np.asarray(g.f_slow).mean()))
        series["wa_slow"].append(float(np.asarray(g.wa_slow).mean()))
        series["wa_fast"].append(float(np.asarray(g.wa_fast).mean()))
        series["wa"].append(float(np.asarray(stats.wa).mean()))
        series["u"].append(float(np.asarray(stats.utilization).mean()))
        # Eqs. (17)-(18) recombination identity holds at every step
        w2 = np.asarray(recombine_w2(g))
        wa = np.asarray(recombine_wa(g))
        if t % 100 == 0:
            dev = np.asarray(state.tau) - np.asarray(state.tau).mean(1)[:, None]
            np.testing.assert_allclose(w2, (dev ** 2).mean(1), rtol=1e-4)
            np.testing.assert_allclose(wa, np.abs(dev).mean(1), rtol=1e-4)
    wa_f = np.array(series["wa_fast"])
    peak_t = int(wa_f.argmax())
    # C7: fast-group width peaks early then decays to a plateau; the slow
    # fraction starts majority (~63% in the paper) and relaxes
    rec = dict(series, peak_t=peak_t)
    assert series["f_slow"][0] > 0.55
    assert 1 <= peak_t < n_steps // 2
    assert wa_f[-1] < wa_f[peak_t]
    # gate: how far the fast-group width has decayed from its transient peak
    # by the end of the run — the double-peak relaxation signature of Fig. 10
    decay = float(wa_f[-1] / wa_f[peak_t])
    _emit("fig10_slow_fast", (time.time() - t0) * 1e6,
          f"f_slow(0)={series['f_slow'][0]:.2f}, wa_fast peak at t={peak_t}, "
          f"u_steady={np.mean(series['u'][-100:]):.3f}", rec,
          gate={"metric": "wa_fast_decay_from_peak", "value": decay,
                "higher_is_better": False})


# ---------------------------------------------------------------------------
# Kernel table — engine backends: fused Pallas vs per-step reference  [B1,B2]
# ---------------------------------------------------------------------------


def bench_kernel_fused(fast=False, backend=None):
    """Wall-time of PDESEngine backends on the identical trajectory.

    All backends consume the same counter event stream (bit-identical tau),
    so this is a pure execution-path comparison: per-step reference scan vs
    fused one-step kernel vs K-fused VMEM-resident kernel with in-kernel
    event generation.  Asserts the multistep backend >= 1.3x the reference
    at B=64, L=1024, K=16 (interpret-mode CPU numbers; on TPU the gap is
    the analytic HBM ratio below).
    """
    import jax
    from repro.core import PDESConfig
    from repro.core.engine import PDESEngine
    t0 = time.time()
    cfg = PDESConfig(L=1024, n_v=10, delta=10.0)
    B, T, K = 64, 64, 16
    # --backend narrows the comparison to reference vs that backend; the
    # multistep speedup claim is only asserted when multistep is timed.
    backends = ["reference", "pallas", "pallas_multistep"] if backend is None \
        else ["reference"] + ([backend] if backend != "reference" else [])
    us_per_step, tau_check = {}, {}
    for b in backends:
        eng = PDESEngine(cfg, backend=b, k_fuse=K)
        state = eng.init(B)
        run = lambda: jax.block_until_ready(eng.run(state, 0, T))
        out = run()                             # compile + parity capture
        tau_check[b] = np.asarray(out[0].tau)
        best = min(_timed(run)[1] for _ in range(3))
        us_per_step[b] = best / T
    for b in backends[1:]:                      # identical trajectories
        assert (tau_check[b] == tau_check["reference"]).all(), b
    speedup = (us_per_step["reference"] / us_per_step["pallas_multistep"]
               if "pallas_multistep" in us_per_step else None)
    # derived: HBM bytes/PE/step — XLA path vs fused kernel vs K-fused kernel
    # with in-kernel events (analytic; see kernels/*.py docstrings)
    xla_bytes = 7 * 4 + 8          # ~7 tau-sized round trips + bits read
    fused_bytes = 2 * 4 + 8        # tau r/w + bits
    kfused_bytes = 2 * 4 / K       # tau r/w amortized; bits generated in VMEM
    rec = {"B": B, "L": cfg.L, "K": K, "n_steps": T,
           "us_per_step": us_per_step,
           "speedup_multistep_vs_reference": speedup,
           "bytes_per_pe_step": {"xla": xla_bytes, "fused": fused_bytes,
                                 "fused_k16_inkernel": kfused_bytes},
           "reduction_fused": xla_bytes / fused_bytes,
           "reduction_k16": xla_bytes / kfused_bytes}
    if speedup is not None:
        assert speedup >= 1.3, rec
    fastest = min(us_per_step, key=us_per_step.get)
    _emit("bench_kernel_fused", us_per_step[fastest],
          f"{fastest} {us_per_step[fastest]:.0f}us/step vs reference "
          f"{us_per_step['reference']:.0f}"
          + (f" (multistep x{speedup:.2f})" if speedup is not None else "")
          + f"; bytes/PE/step {xla_bytes}->{fused_bytes}->{kfused_bytes:.1f}",
          rec,
          gate=None if speedup is None else {
              "metric": "speedup_multistep_vs_reference", "value": speedup,
              "higher_is_better": True})


# ---------------------------------------------------------------------------
# Window-sweep table — batched Δ-axis vs serial per-Δ engine loop
# ---------------------------------------------------------------------------


def bench_window_sweep(fast=False, backend=None):
    """Batched window sweep vs the serial per-Δ loop on identical physics.

    The batched path advances all ``n_windows x replicas`` trajectories in
    one engine pass per grid point (Δ as a per-row operand down to the
    kernel); the serial oracle makes one engine call per Δ on the same
    counter-stream rows, so both produce bit-identical records
    (asserted).  The gate metric is the batched-over-serial speedup — a
    hardware-portable ratio.
    """
    from repro.experiments import (WindowSweep, run_window_sweep,
                                   serial_window_sweep)
    spec = WindowSweep(
        Ls=(128 if fast else 256,), n_vs=(10,),
        deltas=(1.0, 2.0, 4.0, 8.0, 16.0, 32.0, math.inf),
        replicas=8, n_steps=128, burn_in=96,
        backend=backend or "pallas_multistep", seed=3)
    res = run_window_sweep(spec)       # compile both paths before timing
    ser = serial_window_sweep(spec)
    assert res.records == ser.records  # bit-identical, not just statistical
    t_batched = min(_timed(run_window_sweep, spec)[1] for _ in range(3))
    t_serial = min(_timed(serial_window_sweep, spec)[1] for _ in range(3))
    speedup = t_serial / t_batched
    rec = {"spec": {"L": spec.Ls[0], "n_v": 10, "n_windows": spec.n_windows,
                    "replicas": spec.replicas, "n_steps": spec.n_steps,
                    "burn_in": spec.burn_in, "backend": spec.backend},
           "us_batched": t_batched, "us_serial": t_serial,
           "speedup_batched_vs_serial": speedup,
           "u_by_delta": {str(r.delta): r.u for r in res.records}}
    # the bench itself only insists the batched pass is measurably faster;
    # regression *depth* is governed by the --check gate and its --tolerance,
    # not a hard-coded floor here (the ratio baseline is ~2x).
    assert speedup >= 1.05, rec
    _emit("bench_window_sweep", t_batched,
          f"batched {t_batched / 1e3:.0f}ms vs serial {t_serial / 1e3:.0f}ms "
          f"(x{speedup:.2f}) over {spec.n_windows} windows x "
          f"{spec.replicas} replicas, {spec.backend}",
          rec,
          gate={"metric": "speedup_batched_vs_serial", "value": speedup,
                "higher_is_better": True})


# ---------------------------------------------------------------------------
# PDES comm table — exact vs comm-avoiding GVT (B3/B4/B5)
# ---------------------------------------------------------------------------

_COMM_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, math
    import jax
    import numpy as np
    from repro.compat import make_mesh
    from repro.core.horizon import PDESConfig
    from repro.core import distributed as D
    from repro.core.engine import PDESEngine
    from repro.launch.hlo_cost import analyze_hlo

    backend = "__BACKEND__"
    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = PDESConfig(L=4096, n_v=10, delta=100.0)
    out = {}
    for mode, K in [("exact", 16), ("commavoid", 4), ("commavoid", 16),
                    ("commavoid", 64)]:
        dist = D.DistConfig(ens_axes=("data",), ring_axis="model",
                            mode=mode, k_chunk=K)
        lowered = D.lower_sharded(cfg, mesh, n_trials=8, n_steps=64,
                                  dist=dist)
        c = analyze_hlo(lowered.compile().as_text())
        # utilization cost of stale GVT, measured through the engine on the
        # identical counter event stream (exact-GVT modes may use any
        # single-device backend; stale needs a window-base input, so it
        # falls back to the reference backend when the chosen one can't)
        window = "exact" if mode == "exact" else "stale"
        b = backend
        if window == "stale" and b == "pallas_multistep":
            b = "reference"
        eng = PDESEngine(cfg, backend=b, window=window, k_fuse=K)
        st = eng.init(8)
        st = eng.burn_in(st, 1, 200)
        _, mean = eng.run_mean(st, 1, 200)
        out[f"{mode}_K{K}"] = {
            "coll_bytes_per_step": c.coll_bytes / 64,
            "coll_msgs_per_step": c.coll_msgs / 64,
            "utilization": float(np.asarray(mean.utilization).mean()),
        }
    print("RESULT " + json.dumps(out))
""")


def bench_pdes_comm(fast=False, backend=None):
    t0 = time.time()
    env = dict(os.environ, PYTHONPATH="src")
    script = _COMM_SCRIPT.replace("__BACKEND__", backend or "reference")
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    rec = json.loads(line[len("RESULT "):])
    ex = rec["exact_K16"]
    cv = rec["commavoid_K16"]
    msgs_ratio = ex["coll_msgs_per_step"] / max(cv["coll_msgs_per_step"], 1e-9)
    du = ex["utilization"] - cv["utilization"]
    _emit("bench_pdes_comm", (time.time() - t0) * 1e6,
          f"msgs/step {ex['coll_msgs_per_step']:.2f}->"
          f"{cv['coll_msgs_per_step']:.2f} (x{msgs_ratio:.1f} fewer), "
          f"utilization cost {du:+.4f} at K=16, Δ=100", rec,
          gate={"metric": "msgs_reduction_commavoid_K16", "value": msgs_ratio,
                "higher_is_better": True})


# ---------------------------------------------------------------------------
# Sharded window sweep — batched Δ-axis on a 2x4 mesh vs serial per-Δ loop
# ---------------------------------------------------------------------------

_SWEEP_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, math, time
    import numpy as np
    from repro.compat import make_mesh
    from repro.experiments import (WindowSweep, run_window_sweep,
                                   serial_window_sweep)

    fast = __FAST__
    mesh = make_mesh((2, 4), ("data", "model"))
    spec = WindowSweep(
        Ls=(128 if fast else 256,), n_vs=(10,),
        deltas=(1.0, 2.0, 4.0, 8.0, 16.0, 32.0, math.inf),
        replicas=8, n_steps=64, burn_in=64, backend="sharded",
        k_fuse=8, seed=3)
    res = run_window_sweep(spec, mesh=mesh)       # compile both paths
    ser = serial_window_sweep(spec, mesh=mesh)
    # bit-identical records (wa is NaN by the sharded stats contract, and
    # NaN != NaN, so compare field-wise)
    for a, b in zip(res.records, ser.records):
        da, db = a.as_dict(), b.as_dict()
        wa_a, wa_b = da.pop("wa"), db.pop("wa")
        assert da == db, (da, db)
        assert math.isnan(wa_a) and math.isnan(wa_b)

    def timed(fn):
        best = math.inf
        for _ in range(3):
            t0 = time.time()
            fn()
            best = min(best, (time.time() - t0) * 1e6)
        return best

    t_batched = timed(lambda: run_window_sweep(spec, mesh=mesh))
    t_serial = timed(lambda: serial_window_sweep(spec, mesh=mesh))
    out = {
        "spec": {"L": spec.Ls[0], "n_v": 10, "n_windows": spec.n_windows,
                 "replicas": spec.replicas, "n_steps": spec.n_steps,
                 "burn_in": spec.burn_in, "backend": spec.backend,
                 "mesh": {"data": 2, "model": 4}},
        "us_batched": t_batched, "us_serial": t_serial,
        "speedup_batched_vs_serial_sharded": t_serial / t_batched,
        "u_by_delta": {str(r.delta): r.u for r in res.records},
    }
    print("RESULT " + json.dumps(out))
""")


def bench_window_sweep_sharded(fast=False):
    """Mesh-sharded batched window sweep vs the serial per-Δ sharded loop.

    Same contract as ``bench_window_sweep``, one level up the scaling
    ladder: the (Δ, replica) rows shard over a 2x4 CPU mesh (8 fake
    devices, hence the subprocess — the main process keeps the 1-device
    platform), and the batched pass advances all rows in one shard_map
    call per grid point while the serial baseline makes one mesh pass per
    Δ on the same counter-stream rows.  Records are asserted bit-identical
    before timing; the gate metric is the batched-over-serial speedup — a
    hardware-portable ratio.
    """
    t0 = time.time()
    env = dict(os.environ, PYTHONPATH="src")
    script = _SWEEP_SHARDED_SCRIPT.replace("__FAST__", repr(bool(fast)))
    r = subprocess.run([sys.executable, "-c", script],
                       capture_output=True, text=True, env=env)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    rec = json.loads(line[len("RESULT "):])
    speedup = rec["speedup_batched_vs_serial_sharded"]
    # as with bench_window_sweep: the bench only insists batching wins at
    # all; regression depth is the --check gate's job.
    assert speedup >= 1.05, rec
    rec["us_subprocess_total"] = (time.time() - t0) * 1e6
    _emit("bench_window_sweep_sharded", rec["us_batched"],
          f"batched {rec['us_batched'] / 1e3:.0f}ms vs serial "
          f"{rec['us_serial'] / 1e3:.0f}ms (x{speedup:.2f}) over "
          f"{rec['spec']['n_windows']} windows x {rec['spec']['replicas']} "
          f"replicas on a 2x4 mesh",
          rec,
          gate={"metric": "speedup_batched_vs_serial_sharded",
                "value": speedup, "higher_is_better": True})


# ---------------------------------------------------------------------------
# Sweep service — multiplexed request queue vs one-sweep-per-user serial loop
# ---------------------------------------------------------------------------


def bench_sweep_service(fast=False, backend=None):
    """Coalesced service drain vs running each user's sweep separately.

    A queue of six users requests nested Δ grids over the same study
    (prefix-structured, one exact duplicate): the service unions their
    (trial, Δ) rows into a single device pass, computing shared rows once
    and deduping the duplicate spec entirely, while the serial baseline is
    what those users would do without the service — one
    ``run_window_sweep`` each.  Every response is asserted bit-identical
    to its direct run *before* timing, so the speedup is bought by
    coalescing alone, never by changed physics.  The gate metric is the
    coalesced-over-serial speedup (hardware-portable ratio, floor 1.5x).
    """
    from repro.experiments import WindowSweep, run_window_sweep
    from repro.service import SweepService
    G = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, math.inf)
    common = dict(Ls=(128 if fast else 256,), n_vs=(10,), replicas=8,
                  n_steps=128, burn_in=96,
                  backend=backend or "pallas_multistep", seed=3)
    queue = [("alice", G), ("bob", G[:3]), ("carol", G[:5]),
             ("dana", G), ("erin", G[:2]), ("frank", G[:4])]
    specs = [(who, WindowSweep(deltas=d, **common)) for who, d in queue]

    def serve():
        svc = SweepService()
        for who, s in specs:
            svc.submit(s, requester=who)
        return svc, svc.drain()

    def serial():
        return [run_window_sweep(s) for _, s in specs]

    svc, responses = serve()            # compile + identity capture
    directs = serial()
    for resp, direct in zip(responses, directs):
        assert resp.result.records == direct.records, resp.requester
    t_coalesced = min(_timed(lambda: serve())[1] for _ in range(3))
    t_serial = min(_timed(lambda: serial())[1] for _ in range(3))
    speedup = t_serial / t_coalesced
    stats = svc.stats.as_dict()
    rec = {"spec": {"L": common["Ls"][0], "n_v": 10,
                    "replicas": common["replicas"],
                    "n_steps": common["n_steps"],
                    "burn_in": common["burn_in"],
                    "backend": common["backend"],
                    "queue": [(who, len(d)) for who, d in queue]},
           "us_coalesced": t_coalesced, "us_serial": t_serial,
           "speedup_coalesced_vs_serial": speedup,
           "service_stats": stats}
    assert stats["n_passes"] == 1, stats          # one shared device pass
    assert stats["n_deduped"] == 1, stats         # dana rode alice's rows
    assert stats["rows_computed"] < stats["rows_requested"], stats
    assert speedup >= 1.5, rec
    _emit("bench_sweep_service", t_coalesced,
          f"coalesced {t_coalesced / 1e3:.0f}ms vs serial "
          f"{t_serial / 1e3:.0f}ms (x{speedup:.2f}) for "
          f"{stats['n_requests']} requests -> {stats['rows_computed']} "
          f"union rows ({stats['rows_requested']} requested)",
          rec,
          gate={"metric": "speedup_coalesced_vs_serial", "value": speedup,
                "higher_is_better": True})


BENCHES = {
    "fig2": fig2_utilization_evolution,
    "eq8": eq8_uinf_extrapolation,
    "fig4": fig4_kpz_exponents,
    "fig5": fig5_util_vs_L,
    "fig6": fig6_uinf_surface,
    "fig9": fig9_width_saturation,
    "fig10": fig10_slow_fast,
    "kernel": bench_kernel_fused,
    "kernel_fused": bench_kernel_fused,
    "pdes_comm": bench_pdes_comm,
    "window_sweep": bench_window_sweep,
    "window_sweep_sharded": bench_window_sweep_sharded,
    "sweep_service": bench_sweep_service,
}

# ---------------------------------------------------------------------------
# --check: regression gate against committed baselines
# ---------------------------------------------------------------------------


def record_to_bench(record_name: str) -> str | None:
    """BENCHES key for an ``_emit`` record name, by naming convention.

    ``bench_<key>`` records come from the perf-table benches; the figure
    benches are named ``<key>_<description>`` (e.g. ``fig2_utilization_...``).
    Derived rather than hand-mapped so a future bench can never be silently
    dropped from gating by a stale lookup table.
    """
    if record_name.startswith("bench_") and record_name[6:] in BENCHES:
        return record_name[6:]
    head = record_name.split("_", 1)[0]
    return head if head in BENCHES else None


def load_baselines(path: str) -> dict:
    """Baseline records keyed by BENCHES name, from a JSON file or directory."""
    p = pathlib.Path(path)
    files = sorted(p.glob("*.json")) if p.is_dir() else [p]
    out = {}
    for f in files:
        try:
            rec = json.loads(f.read_text())
        except (json.JSONDecodeError, OSError) as e:
            print(f"check: skipping unreadable baseline {f}: {e}")
            continue
        key = record_to_bench(rec.get("name", "")) if isinstance(rec, dict) \
            else None
        if key is not None:
            out[key] = rec
    return out


_META_GATE_KEYS = ("platform", "device_kind", "interpret_mode", "hostname",
                   "cpu_count")


def compare_to_baseline(name: str, baseline: dict, tolerance: float) -> str:
    """One gate decision: "ok", "regressed", or "skipped".

    Prefers the hardware-portable ``gate`` ratio when the baseline and the
    fresh record both carry one with the same metric name.  Otherwise falls
    back to wall time — but only when the machine metadata matches the
    baseline (``_META_GATE_KEYS``), because wall time on different hardware
    classes is not a regression signal.
    """
    fresh = json.loads((OUT / f"{baseline['name']}.json").read_text())
    # workload knobs first: a --fast or --backend-narrowed run measures a
    # different workload, so neither the gate ratio nor wall time compares.
    b_cfg = {k: (baseline.get("meta") or {}).get(k)
             for k in ("fast", "cli_backend")}
    f_cfg = {k: (fresh.get("meta") or {}).get(k)
             for k in ("fast", "cli_backend")}
    if b_cfg != f_cfg:
        print(f"check: {name} skipped — run workload differs from baseline "
              f"({b_cfg} vs {f_cfg})")
        return "skipped"
    b_gate, f_gate = baseline.get("gate"), fresh.get("gate")
    if bool(b_gate) != bool(f_gate):
        # one side measured its gate ratio and the other didn't (e.g. a
        # --backend narrowing skipped the multistep timing): the wall-time
        # fallback would compare different workloads, so don't gate at all.
        print(f"check: {name} skipped — gate metric present on only one "
              f"side (baseline: {bool(b_gate)}, fresh: {bool(f_gate)}); "
              f"run configurations differ")
        return "skipped"
    if b_gate and f_gate and b_gate["metric"] == f_gate["metric"]:
        old, new = float(b_gate["value"]), float(f_gate["value"])
        if b_gate.get("higher_is_better", True):
            ok, floor = new >= old * (1.0 - tolerance), old * (1.0 - tolerance)
            print(f"check: {name} {b_gate['metric']} {old:.3f} -> {new:.3f} "
                  f"(floor {floor:.3f}) {'ok' if ok else 'REGRESSED'}")
        else:
            ok, ceil = new <= old * (1.0 + tolerance), old * (1.0 + tolerance)
            print(f"check: {name} {b_gate['metric']} {old:.3f} -> {new:.3f} "
                  f"(ceiling {ceil:.3f}) {'ok' if ok else 'REGRESSED'}")
        return "ok" if ok else "regressed"
    if b_gate and f_gate:                # both gated, different metrics
        print(f"check: {name} skipped — gate metrics differ "
              f"({b_gate['metric']} vs {f_gate['metric']})")
        return "skipped"
    b_meta, f_meta = baseline.get("meta"), fresh.get("meta")
    if not b_meta or any(b_meta.get(k) != f_meta.get(k)
                         for k in _META_GATE_KEYS):
        print(f"check: {name} skipped — no portable gate metric and machine "
              f"metadata differs from baseline "
              f"({b_meta and {k: b_meta.get(k) for k in _META_GATE_KEYS}} "
              f"vs {({k: f_meta.get(k) for k in _META_GATE_KEYS})})")
        return "skipped"
    if b_meta.get("jax_version") != f_meta.get("jax_version"):
        print(f"check: {name} note — jax {b_meta.get('jax_version')} -> "
              f"{f_meta.get('jax_version')}")
    old, new = float(baseline["us_per_call"]), float(fresh["us_per_call"])
    ok = new <= old * (1.0 + tolerance)
    print(f"check: {name} us_per_call {old:.1f} -> {new:.1f} "
          f"(ceiling {old * (1 + tolerance):.1f}) "
          f"{'ok' if ok else 'REGRESSED'}")
    return "ok" if ok else "regressed"


def main(argv=None) -> None:
    import inspect
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--backend", default=None,
                    choices=["reference", "pallas", "pallas_multistep"],
                    help="route engine-aware benches (kernel_fused, "
                         "pdes_comm, window_sweep) through this PDESEngine "
                         "backend")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="baseline JSON file or directory (e.g. "
                         "results/benchmarks); re-run the benchmarks found "
                         "there and fail on perf regressions beyond "
                         "--tolerance")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="allowed relative regression of the gate metric "
                         "(default 0.25)")
    ap.add_argument("--trace", default=None, metavar="FILE",
                    help="save the full Chrome-trace JSON of the run (the "
                         "per-bench phases_us breakdown is recorded either "
                         "way)")
    args = ap.parse_args(argv)
    _RUN_CONFIG.update(fast=args.fast, cli_backend=args.backend)
    global _TRACER
    _TRACER = TraceRecorder()
    set_tracer(_TRACER)           # library burn/measure/reduce spans
    baselines = None
    if args.check is not None:
        baselines = load_baselines(args.check)
        if not baselines:
            raise SystemExit(f"--check: no readable baselines in "
                             f"{args.check}")
        # every --only name still RUNS (its claim asserts execute); only the
        # gate comparison needs a baseline.  Gating nothing is an error, not
        # a green job.
        names = args.only.split(",") if args.only else list(baselines)
        unknown = sorted(set(names) - set(BENCHES))
        if unknown:
            raise SystemExit(f"--check: unknown benchmark(s) {unknown}; "
                             f"known: {sorted(set(BENCHES))}")
        # normalize aliases that share one record/baseline (kernel -> _fused)
        names = list(dict.fromkeys(
            "kernel_fused" if n == "kernel" else n for n in names))
        missing = sorted(set(names) - set(baselines))
        if missing:
            print(f"check: no baseline for {missing}; run but not gated")
        if not set(names) & set(baselines):
            raise SystemExit("--check: none of the requested benchmarks "
                             "have a baseline — nothing would be gated")
        # fresh records go to a scratch dir so the committed baselines on
        # disk are never overwritten by the very run that gates against them
        global OUT
        OUT = pathlib.Path(tempfile.mkdtemp(prefix="bench-fresh-"))
        print(f"check: fresh records -> {OUT}")
    else:
        names = args.only.split(",") if args.only else list(BENCHES)
        if args.only is None:
            names.remove("kernel")    # alias of kernel_fused; run once
    print("name,us_per_call,derived")
    failures, regressions, gated = [], [], 0
    for n in names:
        fn = BENCHES[n]
        kw = {"fast": args.fast}
        if args.backend and "backend" in inspect.signature(fn).parameters:
            kw["backend"] = args.backend
        try:
            with _TRACER.span(f"bench:{n}", cat="bench"):
                fn(**kw)
        except AssertionError as e:  # report, keep going
            failures.append((n, str(e)[:200]))
            print(f"{n},0,FAILED: {str(e)[:120]}")
            _phase_breakdown()     # drop the failed bench's spans
            continue
        if baselines is not None and n in baselines:
            verdict = compare_to_baseline(n, baselines[n], args.tolerance)
            if verdict == "regressed":
                regressions.append(n)
            if verdict != "skipped":
                gated += 1
    if args.trace:
        _TRACER.save(args.trace)
        print(f"trace: {len(_TRACER)} span(s) -> {args.trace}")
    if failures:
        raise SystemExit(f"{len(failures)} benchmark claims failed: "
                         f"{[f[0] for f in failures]}")
    if regressions:
        raise SystemExit(f"perf regression beyond tolerance "
                         f"{args.tolerance} in: {regressions}")
    if baselines is not None and not gated:
        # every comparison was skipped (workload/machine mismatch): a green
        # exit would claim a gate that never ran.
        raise SystemExit("--check: every baseline comparison was skipped — "
                         "nothing was gated (workload or machine mismatch)")


if __name__ == "__main__":
    main()

"""Batched serving demo: continuous batching with Δ-window lane sync.

Serves a reduced llama3.2 model (random weights — the point is the engine
path: prefill, KV-cache decode, lane scheduling, bounded head-of-line
blocking) and reports lane utilization vs the paper's prediction.

Usage: PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.core.theory import u_rd
from repro.models import build_model
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    delta = 16.0
    eng = ServeEngine(model, params, batch_lanes=4, max_len=64, delta=delta)
    rng = np.random.default_rng(0)
    for uid in range(8):
        eng.submit(Request(
            uid=uid,
            prompt=rng.integers(0, cfg.vocab_size, rng.integers(4, 12),
                                dtype=np.int32),
            max_new_tokens=int(rng.integers(4, 12))))
    results = eng.run()
    for uid in sorted(results):
        r = results[uid]
        print(f"request {uid}: {len(r.tokens)} tokens -> {r.tokens}")
    print(f"lane utilization: {eng.lane_utilization:.3f} "
          f"(paper fit u_RD(Δ={delta:.0f}) = {float(u_rd(delta)):.3f})")


if __name__ == "__main__":
    main()

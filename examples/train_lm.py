"""End-to-end driver: train a ~135M-param llama-style model for a few hundred
steps with the Δ-window scheduler, deterministic pipeline, checkpointing,
and (optionally) injected node failures.

This wraps repro.launch.train with a ~100M config, per the deliverable
"train ~100M model for a few hundred steps".  On CPU this takes a while at
full size — pass --reduced for a fast smoke run of the same code path.

Usage:
  PYTHONPATH=src python examples/train_lm.py --steps 300          # ~135M params
  PYTHONPATH=src python examples/train_lm.py --steps 300 --reduced --fail-at 100
"""
import argparse

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    args = ap.parse_args()
    argv = ["--arch", "mamba2-130m",          # 135M params: the ~100M deliverable
            "--steps", str(args.steps), "--batch", "4", "--seq", "512",
            "--ckpt-every", "100"]
    if args.reduced:
        argv.append("--reduced")
    if args.fail_at:
        argv += ["--fail-at"] + [str(s) for s in args.fail_at]
    train_main(argv)


if __name__ == "__main__":
    main()

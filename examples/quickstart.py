"""Quickstart: the paper in ~30 seconds on CPU.

Runs the Δ-window constrained conservative PDES, shows the two scalability
claims side by side:
  * simulation phase: utilization stays finite as the ring grows;
  * measurement phase: the Δ-window bounds the time-horizon width that
    diverges without it.

Usage: PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import PDESConfig, ensemble, theory


def main():
    print("=== unconstrained (paper Secs. III, Korniss et al. 2000) ===")
    for L in (32, 128, 512):
        ss = ensemble.steady_state(PDESConfig(L=L, n_v=1), n_trials=32,
                                   seed=L, measure_steps=1500)
        print(f"  L={L:4d}: utilization={ss.utilization:.4f} "
              f"(paper u_inf={theory.U_INF_KPZ_NV1:.4f})  width w={ss.w:.2f}"
              f"  <- width grows ~sqrt(L): measurement phase NOT scalable")

    print("=== Δ-window constrained (the paper's contribution) ===")
    for delta in (5.0, 10.0):
        for L in (32, 128, 512):
            ss = ensemble.steady_state(
                PDESConfig(L=L, n_v=1, delta=delta), n_trials=32, seed=L,
                measure_steps=1500)
            print(f"  Δ={delta:5.1f} L={L:4d}: u={ss.utilization:.4f} "
                  f"w={ss.w:.2f} (bounded by Δ) rate={ss.rate:.3f}")

    print("=== capacity planning with the paper's own fits (Appendix) ===")
    for delta in (2.0, 10.0, 100.0):
        print(f"  Δ={delta:6.1f}: predicted cluster utilization "
              f"u_RD={float(theory.u_rd(delta)):.3f} "
              f"(what a Δ-window DP training cluster achieves with "
              f"Exp(1)-spread stragglers)")


if __name__ == "__main__":
    main()

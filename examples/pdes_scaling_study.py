"""Scaling study: reproduce the shape of paper Figs. 5/6 at laptop scale.

Sweeps (L, N_V, Δ), extrapolates u_inf, and compares with the paper's
composite fit Eq. (12).  Writes results/example_scaling.json.

Usage: PYTHONPATH=src python examples/pdes_scaling_study.py [--fast]
           [--backend reference|pallas|pallas_multistep]

``--backend`` routes every simulation through the unified ``PDESEngine``
(repro.core.engine) instead of the legacy jax.random-keyed scan — on real
TPU hardware ``pallas_multistep`` is the fast path for exactly this kind of
sweep.
"""
import argparse
import json
import pathlib


from repro.core import PDESConfig, ensemble, scaling, theory


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--backend", default=None,
                    choices=["reference", "pallas", "pallas_multistep"],
                    help="route the sweep through this PDESEngine backend")
    args = ap.parse_args()
    Ls = [32, 64, 128, 256] if args.fast else [64, 128, 256, 512, 1024]
    out = {"backend": args.backend or "legacy-horizon"}
    for delta in (5.0, 20.0):
        for nv in (1, 10, "rd"):
            us = []
            for L in Ls:
                cfg = PDESConfig(L=L, n_v=1 if nv == "rd" else nv,
                                 delta=delta, rd_mode=(nv == "rd"))
                ss = ensemble.steady_state(cfg, n_trials=16, seed=L,
                                           backend=args.backend)
                us.append(ss.utilization)
            ex = scaling.rational_extrapolate(Ls, us)
            nv_eff = 1e8 if nv == "rd" else nv
            fit = float(theory.u_composite(nv_eff, delta))
            out[f"delta{delta}_nv{nv}"] = {
                "L": Ls, "u": us, "u_inf": ex.u_inf, "paper_fit": fit}
            print(f"Δ={delta:5.1f} N_V={str(nv):>3s}: "
                  f"u(L): {', '.join(f'{u:.3f}' for u in us)}  "
                  f"-> u_inf={ex.u_inf:.3f}  paper Eq.(12)={fit:.3f}")
    p = pathlib.Path("results/example_scaling.json")
    p.parent.mkdir(exist_ok=True)
    p.write_text(json.dumps(out, indent=1))
    print(f"wrote {p}")


if __name__ == "__main__":
    main()

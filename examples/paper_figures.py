"""Emit the paper's figure data from batched window sweeps.

Reproduces the qualitative content of the systematic study in
Kolakowska & Novotny (cs/0211013) with one ``WindowSweep`` per figure:

* ``fig_util_vs_L``        — steady-state utilization vs ring size L at
  fixed window Δ: u(L) levels off at a nonzero plateau (the computation
  phase scales), with the unconstrained Δ=inf curve as contrast.
* ``fig_w2_vs_delta``      — steady-state ⟨w²⟩ vs Δ at fixed L: the window
  bounds the virtual-time-horizon width, and the bound tightens as Δ
  shrinks (the measurement phase scales).
* ``fig_rate_vs_delta``    — average progress rate vs Δ: the constraint
  controls the rate of global progress.
* ``fig_efficiency_vs_delta`` — efficiency u/(1+w) vs Δ: an *interior* Δ*
  maximizes it, the paper's tuning-parameter claim
  (repro.experiments.optimal_window).

Each figure's data is written to results/figures/<name>.json; the
qualitative claims are asserted before writing, so a successful run is
itself a reproduction check.

Usage: PYTHONPATH=src python examples/paper_figures.py [--fast]
           [--backend reference|pallas|pallas_multistep]
"""
from __future__ import annotations

import argparse
import json
import math
import pathlib

from repro.experiments import (WindowSweep, find_optimal_window,
                               run_window_sweep)

OUT = pathlib.Path("results/figures")


def _write(name: str, payload: dict) -> None:
    OUT.mkdir(parents=True, exist_ok=True)
    p = OUT / f"{name}.json"
    p.write_text(json.dumps(payload, indent=1))
    print(f"wrote {p}")


def _delta_key(d: float) -> str:
    return "inf" if math.isinf(d) else f"{d:g}"


def fig_util_vs_L(backend: str, fast: bool) -> None:
    """u(L) at fixed Δ saturates with L (computation + measurement scale)."""
    Ls = (16, 32, 64, 128) if fast else (16, 32, 64, 128, 256)
    spec = WindowSweep(
        Ls=Ls, n_vs=(1, 10), deltas=(4.0, math.inf),
        replicas=8 if fast else 16, n_steps=200 if fast else 400,
        burn_in=400 if fast else None, backend=backend, seed=11)
    res = run_window_sweep(spec)
    curves = {}
    for n_v in spec.n_vs:
        for d in spec.deltas:
            recs = [r for r in res.select(n_v=n_v, delta=d)]
            recs.sort(key=lambda r: r.L)
            curves[f"nv{n_v}_d{_delta_key(d)}"] = {
                "L": [r.L for r in recs],
                "u": [r.u for r in recs],
                "u_err": [r.u_err for r in recs],
            }
    # claim: constrained utilization levels off at a nonzero plateau —
    # the last L-doubling moves u by a few percent at most.
    for n_v in spec.n_vs:
        u = curves[f"nv{n_v}_d4"]["u"]
        assert u[-1] > 0.1, u
        assert abs(u[-1] - u[-2]) < 0.1 * u[-2] + 0.02, u
    _write("fig_util_vs_L", {"spec_deltas": [_delta_key(d)
                                             for d in spec.deltas],
                             "curves": curves})


def _delta_sweep(backend: str, fast: bool) -> tuple[WindowSweep, object]:
    deltas = ((0.5, 2.0, 8.0, math.inf) if fast
              else (0.5, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, math.inf))
    spec = WindowSweep(
        Ls=(64,) if fast else (128,), n_vs=(1, 10), deltas=deltas,
        replicas=8 if fast else 16, n_steps=300 if fast else 600,
        burn_in=400 if fast else None, backend=backend, seed=29)
    return spec, run_window_sweep(spec)


def fig_w2_and_rate_vs_delta(spec, res) -> None:
    """⟨w²⟩ bounded by the window and shrinking with Δ; rate controlled."""
    L = spec.Ls[0]
    w2_out, rate_out = {}, {}
    for n_v in spec.n_vs:
        recs = sorted(res.select(L=L, n_v=n_v), key=lambda r: r.delta)
        finite = [r for r in recs if not math.isinf(r.delta)]
        unc = [r for r in recs if math.isinf(r.delta)][0]
        key = f"L{L}_nv{n_v}"
        w2_out[key] = {
            "delta": [_delta_key(r.delta) for r in recs],
            "w2": [r.w2 for r in recs], "w2_err": [r.w2_err for r in recs],
            "spread": [r.spread for r in recs],
        }
        rate_out[key] = {
            "delta": [_delta_key(r.delta) for r in recs],
            "rate": [r.rate for r in recs],
            "rate_err": [r.rate_err for r in recs],
            "u": [r.u for r in recs],
        }
        # claims: (a) every *binding* window (Δ below the unconstrained
        # width — wider windows rarely act and just reproduce the
        # unconstrained noise) keeps ⟨w²⟩ at or below the unconstrained
        # saturation level, (b) tightening the window tightens the width —
        # ⟨w²⟩ is non-decreasing in Δ, and the smallest window beats the
        # widest by a clear margin, (c) the horizon extent obeys the hard
        # bound Δ + max increment for every finite Δ.
        binding = [r for r in finite if r.delta <= math.sqrt(unc.w2)]
        assert binding and all(r.w2 <= unc.w2 * 1.15 for r in binding), \
            w2_out[key]
        w2s = [r.w2 for r in finite]
        assert all(b >= a - 0.15 * max(a, 0.1)
                   for a, b in zip(w2s, w2s[1:])), w2s
        assert w2s[0] < 0.7 * max(w2s[-1], unc.w2), w2s
        eta_max = 25 * math.log(2)           # decode_words: -log(2^-25)
        assert all(r.spread <= r.delta + eta_max for r in finite), w2_out[key]
        # claim: the window throttles global progress — rate grows with Δ.
        rates = [r.rate for r in finite]
        assert rates[0] < rates[-1] + 1e-3, rates
    _write("fig_w2_vs_delta", w2_out)
    _write("fig_rate_vs_delta", rate_out)


def fig_efficiency_vs_delta(spec, res) -> None:
    """Efficiency u/(1+w) has an interior maximizer Δ* (tuning parameter)."""
    out = {}
    interior_seen = False
    for n_v in spec.n_vs:
        ow = find_optimal_window(res, L=spec.Ls[0], n_v=n_v)
        out[f"L{ow.L}_nv{ow.n_v}"] = ow.as_dict()
        interior_seen |= ow.interior
        print(f"  L={ow.L} n_v={ow.n_v}: delta*={ow.delta_star:g} "
              f"eff={ow.eff_star:.4f} interior={ow.interior}")
    assert interior_seen, out   # the paper's claim: Δ* is a true optimum
    _write("fig_efficiency_vs_delta", out)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--backend", default="pallas_multistep",
                    choices=["reference", "pallas", "pallas_multistep"])
    args = ap.parse_args(argv)
    fig_util_vs_L(args.backend, args.fast)
    spec, res = _delta_sweep(args.backend, args.fast)  # shared by two figures
    fig_w2_and_rate_vs_delta(spec, res)
    fig_efficiency_vs_delta(spec, res)
    print("all paper-figure claims hold")


if __name__ == "__main__":
    main()

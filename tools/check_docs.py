#!/usr/bin/env python
"""Markdown link / anchor / orphan checker for the documentation layer.

Usage::

    python tools/check_docs.py README.md docs

Checks, for every ``.md`` file given (directories are walked):

* **relative links** — ``[text](target)`` targets that are not absolute
  URLs must exist on disk, relative to the linking file;
* **anchors** — a ``target#fragment`` (or bare ``#fragment``) must match a
  heading in the target file after GitHub slugification (lowercase,
  spaces -> dashes, punctuation dropped);
* **orphans** — every checked file except the roots (``README.md`` and
  files directly at a given path) must be linked from some other checked
  file, so a doc can't silently fall out of the tree.

Zero dependencies (stdlib only) so the CI docs job needs nothing beyond a
checkout; exits nonzero with one line per problem.
"""
from __future__ import annotations

import pathlib
import re
import sys

# [text](target) — excluding images' leading "!" is unnecessary: image
# targets must exist too.  Nested brackets/parens in link text or URLs are
# not used in this repo's docs.
_LINK = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^(#{1,6})\s+(.*)$", re.MULTILINE)
_FENCE = re.compile(r"```.*?```", re.DOTALL)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug for a heading (lowercase, dashes, no punct)."""
    h = re.sub(r"`([^`]*)`", r"\1", heading.strip())      # drop code spans
    h = h.lower()
    h = re.sub(r"[^\w\- ]", "", h, flags=re.UNICODE)      # drop punctuation
    return h.replace(" ", "-")


def strip_code(text: str) -> str:
    """Remove fenced code blocks (links inside them are examples, not links)."""
    return _FENCE.sub("", text)


def heading_slugs(path: pathlib.Path) -> set[str]:
    """All heading anchors a file exposes (with GitHub's -1, -2 dedup)."""
    slugs: dict[str, int] = {}
    out = set()
    for m in _HEADING.finditer(strip_code(path.read_text())):
        s = github_slug(m.group(2))
        n = slugs.get(s, 0)
        slugs[s] = n + 1
        out.add(s if n == 0 else f"{s}-{n}")
    return out


def collect(paths: list[str]) -> list[pathlib.Path]:
    """Expand the CLI args into the list of markdown files to check."""
    files = []
    for p in map(pathlib.Path, paths):
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        else:
            files.append(p)
    return files


def check(paths: list[str]) -> list[str]:
    """Run all checks; returns a list of problem strings (empty = clean)."""
    files = collect(paths)
    problems = []
    missing = [f for f in files if not f.exists()]
    if missing:
        return [f"missing input: {f}" for f in missing]
    roots = {f.resolve() for f in files
             if f.name == "README.md" or f.parent == pathlib.Path(".")}
    linked: set[pathlib.Path] = set()
    for f in files:
        text = strip_code(f.read_text())
        for m in _LINK.finditer(text):
            target = m.group(1)
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # absolute URL
                continue
            target, _, frag = target.partition("#")
            tpath = f if not target else (f.parent / target)
            if not tpath.exists():
                problems.append(f"{f}: broken link -> {m.group(1)}")
                continue
            if tpath.suffix == ".md":
                linked.add(tpath.resolve())
            if frag and tpath.suffix == ".md":
                if github_slug(frag) not in heading_slugs(tpath):
                    problems.append(
                        f"{f}: broken anchor -> {m.group(1)} "
                        f"(no heading slug {github_slug(frag)!r} "
                        f"in {tpath})")
    for f in files:
        if f.resolve() not in roots and f.resolve() not in linked:
            problems.append(
                f"{f}: orphan — not linked from any checked document")
    return problems


def main(argv: list[str]) -> int:
    """CLI entry point; returns the exit code."""
    if not argv:
        print(__doc__)
        return 2
    problems = check(argv)
    for p in problems:
        print(p)
    if not problems:
        print(f"docs check OK ({len(collect(argv))} file(s))")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
